"""openCypher-subset surface — ``MATCH`` path chains lowered onto the
CPQ/RPQ engines.

The accepted subset is the path-query core of the openCypher corpus
(SNIPPETS.md Snippet 1): one linear ``MATCH`` chain of nodes and typed
relationships, variable-length hops, inverse direction, endpoint pins::

    MATCH (a)-[:F]->(b)-[:V*1..3]->(c) WHERE a = 5 RETURN a, c
    MATCH (x)<-[:KNOWS|LIKES*]-(y) RETURN *

* relationships must be typed and directed: ``-[:L]->``, ``<-[:L]-``,
  multi-type alternation ``[:A|B]``, variable length ``*``, ``*n``,
  ``*n..m``, ``*n..``, ``*..m``, ``*0..``;
* ``WHERE`` takes ``AND``-joined endpoint pins ``var = <vertex id>``
  (``id(var) = <id>`` accepted as a synonym) — pins on interior nodes
  have no RPQ lowering and are rejected;
* ``RETURN`` must project exactly the chain endpoints (either order) or
  ``*``.

Everything else in the corpus — ``WITH``, ``ORDER BY``, ``LIMIT``,
``OPTIONAL MATCH``, node labels ``(c:Concept)``, property maps and
projections, aggregates — raises :class:`UnsupportedCypher` *naming the
construct*, so a caller porting a workload learns exactly which clause
to rewrite.

Lowering (:func:`lower_cypher`) is language-aware: a chain whose hops
are all single-type and fixed-length is a **pure CPQ** and lowers to the
existing :mod:`repro.core.query` AST — the cost-based optimizer, plan
cache and union dispatch serve it untouched, byte-identical to a
hand-written ``parse()`` query.  Anything with a star/plus/optional or
a type alternation lowers to the :mod:`repro.core.rpq` AST and runs as
an automaton fixpoint of per-sequence lookups.  ``render_cypher`` is the
inverse of ``parse_cypher`` on canonical queries — the round-trip
property the tests pin.
"""

from __future__ import annotations

import dataclasses
import re

from .query import CPQ, Edge, Join
from .rpq import RAlt, RConcat, ROpt, RPlus, RPQ, RStar, RSym


class UnsupportedCypher(ValueError):
    """Raised when a query uses openCypher outside the served subset;
    the message names the offending clause/construct."""


# ---------------------------------------------------------------------- #
# query form
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Rel:
    """One relationship hop.  ``types`` are label *names* (resolution to
    closure ids happens at lowering); ``back`` marks ``<-[...]-``;
    (``lo``, ``hi``) are the variable-length bounds, ``hi=None`` means
    unbounded, a fixed hop is ``(1, 1)``."""

    types: tuple
    back: bool = False
    lo: int = 1
    hi: int | None = 1


@dataclasses.dataclass(frozen=True)
class CypherQuery:
    """Parsed form of one accepted query: a linear chain of ``nodes``
    (variable names, ``""`` for anonymous) joined by ``rels``, endpoint
    ``pins`` (var, vertex id), and the ``RETURN`` projection (``()``
    for ``RETURN *``)."""

    nodes: tuple
    rels: tuple
    pins: tuple = ()
    returns: tuple = ()


# ---------------------------------------------------------------------- #
# parser
# ---------------------------------------------------------------------- #

_CLAUSES = ("OPTIONAL MATCH", "WITH", "ORDER BY", "LIMIT", "SKIP",
            "CREATE", "MERGE", "DELETE", "DETACH", "SET", "REMOVE",
            "UNWIND", "CALL", "UNION", "FOREACH")

_NAME = r"[A-Za-z_][A-Za-z_0-9]*"
_WS = re.compile(r"\s+")


class _Scanner:
    def __init__(self, text: str):
        self.text = text
        self.pos = 0

    def skip_ws(self) -> None:
        m = _WS.match(self.text, self.pos)
        if m:
            self.pos = m.end()

    def peek_word(self) -> str:
        self.skip_ws()
        m = re.compile(_NAME).match(self.text, self.pos)
        return m.group(0) if m else ""

    def take_word(self) -> str:
        w = self.peek_word()
        self.pos += len(w)
        return w

    def accept(self, lit: str) -> bool:
        self.skip_ws()
        if self.text.startswith(lit, self.pos):
            self.pos += len(lit)
            return True
        return False

    def expect(self, lit: str, what: str) -> None:
        if not self.accept(lit):
            raise SyntaxError(
                f"Cypher syntax error at position {self.pos}: expected "
                f"{lit!r} in {what} (got {self.text[self.pos:self.pos+12]!r})")

    def at_end(self) -> bool:
        self.skip_ws()
        return self.pos >= len(self.text)


def _check_unsupported_clauses(text: str) -> None:
    upper = text.upper()
    for clause in _CLAUSES:
        m = re.search(r"(?<![A-Za-z_0-9])" + clause.replace(" ", r"\s+")
                      + r"(?![A-Za-z_0-9])", upper)
        if m:
            raise UnsupportedCypher(
                f"unsupported Cypher clause: {clause} (at position "
                f"{m.start()}) — the served subset is a single MATCH "
                "chain with WHERE endpoint pins and RETURN of the "
                "endpoints")
    if re.search(r"(?<![A-Za-z_0-9])DISTINCT(?![A-Za-z_0-9])", upper):
        raise UnsupportedCypher("unsupported Cypher construct: DISTINCT")
    for fn in ("COUNT", "COLLECT", "LABELS", "TYPE"):
        if re.search(r"(?<![A-Za-z_0-9])" + fn + r"\s*\(", upper):
            raise UnsupportedCypher(
                f"unsupported Cypher construct: {fn.lower()}() call")


def parse_cypher(text: str) -> CypherQuery:
    """Parse one query of the served subset into a :class:`CypherQuery`.
    Raises :class:`UnsupportedCypher` (naming the construct) for
    anything outside it, and ``SyntaxError`` (with position) for text
    that is not Cypher at all."""
    _check_unsupported_clauses(text)
    sc = _Scanner(text)
    word = sc.take_word()
    if word.upper() != "MATCH":
        raise SyntaxError(
            f"Cypher syntax error at position 0: expected MATCH "
            f"(got {word or text[:12]!r})")

    nodes = [_parse_node(sc)]
    rels: list[Rel] = []
    while True:
        sc.skip_ws()
        if sc.text.startswith(("-", "<"), sc.pos):
            rels.append(_parse_rel(sc))
            nodes.append(_parse_node(sc))
        else:
            break
    if not rels:
        raise UnsupportedCypher(
            "unsupported Cypher construct: single-node MATCH (no "
            "relationship) — a path query needs at least one hop")

    pins: list[tuple] = []
    if sc.peek_word().upper() == "WHERE":
        sc.take_word()
        while True:
            pins.append(_parse_pin(sc, nodes))
            if sc.peek_word().upper() == "AND":
                sc.take_word()
                continue
            break

    if sc.peek_word().upper() != "RETURN":
        raise SyntaxError(
            f"Cypher syntax error at position {sc.pos}: expected RETURN")
    sc.take_word()
    returns = _parse_returns(sc, nodes)
    if not sc.at_end():
        if sc.accept(";") and sc.at_end():
            pass
        else:
            raise SyntaxError(
                f"Cypher syntax error at position {sc.pos}: trailing "
                f"input {sc.text[sc.pos:sc.pos+12]!r}")
    return CypherQuery(nodes=tuple(nodes), rels=tuple(rels),
                       pins=tuple(pins), returns=tuple(returns))


def _parse_node(sc: _Scanner) -> str:
    sc.expect("(", "node pattern")
    name = sc.take_word()
    sc.skip_ws()
    if sc.text.startswith(":", sc.pos):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: node label (at position "
            f"{sc.pos}) — the graph model has edge labels only")
    if sc.text.startswith("{", sc.pos):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: property map (at position "
            f"{sc.pos}) — pin endpoints with WHERE var = <vertex id>")
    sc.expect(")", "node pattern")
    return name


def _parse_rel(sc: _Scanner) -> Rel:
    back = sc.accept("<")
    sc.expect("-", "relationship")
    sc.expect("[", "relationship")
    sc.take_word()  # optional relationship variable, ignored
    sc.skip_ws()
    if not sc.text.startswith(":", sc.pos):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: untyped relationship (at "
            f"position {sc.pos}) — every hop must name its type(s)")
    sc.pos += 1
    types = [_expect_name(sc, "relationship type")]
    while sc.accept("|"):
        sc.accept(":")  # legacy [:A|:B] form
        types.append(_expect_name(sc, "relationship type"))
    lo, hi = 1, 1
    if sc.accept("*"):
        lo, hi = _parse_bounds(sc)
    sc.skip_ws()
    if sc.text.startswith("{", sc.pos):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: relationship property map "
            f"(at position {sc.pos})")
    sc.expect("]", "relationship")
    sc.expect("-", "relationship")
    fwd = sc.accept(">")
    if back and fwd:
        raise SyntaxError(
            f"Cypher syntax error at position {sc.pos}: relationship "
            "cannot point both ways")
    if not back and not fwd:
        raise UnsupportedCypher(
            f"unsupported Cypher construct: undirected relationship "
            f"(at position {sc.pos}) — use -[:L]-> or <-[:L]-")
    return Rel(types=tuple(types), back=back, lo=lo, hi=hi)


def _parse_bounds(sc: _Scanner) -> tuple[int, int | None]:
    lo_digits = _take_digits(sc)
    if sc.accept(".."):
        hi_digits = _take_digits(sc)
        lo = int(lo_digits) if lo_digits else 1
        hi = int(hi_digits) if hi_digits else None
    elif lo_digits:
        lo = hi = int(lo_digits)  # *n == exactly n
    else:
        lo, hi = 1, None  # bare * == one or more
    if hi is not None and hi < lo:
        raise SyntaxError(
            f"Cypher syntax error at position {sc.pos}: empty "
            f"variable-length range *{lo}..{hi}")
    return lo, hi


def _take_digits(sc: _Scanner) -> str:
    sc.skip_ws()
    m = re.compile(r"\d+").match(sc.text, sc.pos)
    if not m:
        return ""
    sc.pos = m.end()
    return m.group(0)


def _expect_name(sc: _Scanner, what: str) -> str:
    sc.skip_ws()
    name = sc.take_word()
    if not name:
        raise SyntaxError(
            f"Cypher syntax error at position {sc.pos}: expected {what}")
    return name


def _parse_pin(sc: _Scanner, nodes: list) -> tuple:
    var = _expect_name(sc, "pinned variable in WHERE")
    if var == "id" and sc.accept("("):
        var = _expect_name(sc, "pinned variable in WHERE")
        sc.expect(")", "WHERE pin")
    if sc.accept("."):
        prop = sc.take_word()
        raise UnsupportedCypher(
            f"unsupported Cypher construct: property predicate "
            f"{var}.{prop} in WHERE — only endpoint pins "
            "var = <vertex id> are served")
    sc.expect("=", "WHERE pin")
    digits = _take_digits(sc)
    if not digits:
        raise UnsupportedCypher(
            f"unsupported Cypher construct: non-integer WHERE "
            f"comparison on {var} — pins are vertex ids")
    if var not in (nodes[0], nodes[-1]):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: WHERE pin on interior node "
            f"{var!r} — only the chain endpoints "
            f"({(nodes[0] or '?')!r}, {(nodes[-1] or '?')!r}) can be "
            "pinned")
    return (var, int(digits))


def _parse_returns(sc: _Scanner, nodes: list) -> tuple:
    if sc.accept("*"):
        return ()
    out = [_expect_name(sc, "RETURN item")]
    while True:
        sc.skip_ws()
        if sc.text.startswith(".", sc.pos):
            raise UnsupportedCypher(
                f"unsupported Cypher construct: property projection "
                f"{out[-1]}.<prop> in RETURN — endpoints only")
        if sc.peek_word().upper() == "AS":
            raise UnsupportedCypher(
                "unsupported Cypher construct: AS alias in RETURN")
        if sc.accept(","):
            out.append(_expect_name(sc, "RETURN item"))
            continue
        break
    ends = {n for n in (nodes[0], nodes[-1]) if n}
    extra = [v for v in out if v not in ends]
    if extra or len(set(out)) != len(ends):
        raise UnsupportedCypher(
            f"unsupported Cypher construct: RETURN must project exactly "
            f"the chain endpoints {sorted(ends)} (got {out}) — interior "
            "bindings are not materialized")
    return tuple(out)


# ---------------------------------------------------------------------- #
# renderer (inverse of the parser on canonical queries)
# ---------------------------------------------------------------------- #


def render_cypher(q: CypherQuery) -> str:
    """Canonical text of a :class:`CypherQuery` —
    ``parse_cypher(render_cypher(q)) == q`` (the tests' round-trip
    property)."""
    parts = ["MATCH ", f"({q.nodes[0]})"]
    for rel, node in zip(q.rels, q.nodes[1:]):
        star = ""
        if (rel.lo, rel.hi) != (1, 1):
            if (rel.lo, rel.hi) == (1, None):
                star = "*"
            elif rel.hi is None:
                star = f"*{rel.lo}.."
            elif rel.lo == rel.hi:
                star = f"*{rel.lo}"
            else:
                star = f"*{rel.lo}..{rel.hi}"
        body = f"[:{'|'.join(rel.types)}{star}]"
        parts.append(f"<-{body}-" if rel.back else f"-{body}->")
        parts.append(f"({node})")
    if q.pins:
        parts.append(" WHERE " + " AND ".join(
            f"{v} = {i}" for v, i in q.pins))
    parts.append(" RETURN ")
    parts.append(", ".join(q.returns) if q.returns else "*")
    return "".join(parts)


# ---------------------------------------------------------------------- #
# lowering — CypherQuery -> CPQ (pure shapes) | RPQ
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class LoweredQuery:
    """Result of :func:`lower_cypher`: the query AST (a CPQ when the
    chain is star/alternation-free — served by the untouched
    ``plan_query``/optimizer path — an RPQ otherwise) plus the endpoint
    pins (vertex ids or None)."""

    ast: object  # CPQ | RPQ
    src: int | None = None
    dst: int | None = None

    @property
    def is_cpq(self) -> bool:
        return isinstance(self.ast, CPQ)


def _resolve_type(name: str, label_ids, n_labels: int) -> int:
    if label_ids and name in label_ids:
        base = label_ids[name]
    elif re.fullmatch(r"l\d+", name):
        base = int(name[1:])
    else:
        raise UnsupportedCypher(
            f"unknown relationship type {name!r} — known types: "
            f"{sorted(label_ids) if label_ids else 'l0..l<n>'}")
    if not 0 <= base < n_labels:
        raise UnsupportedCypher(f"relationship type id {base} out of range")
    return base


def _is_pure_cpq(q: CypherQuery) -> bool:
    return all(len(r.types) == 1 and (r.lo, r.hi) == (1, 1) for r in q.rels)


def lower_cypher(q: CypherQuery, label_ids, n_labels: int) -> LoweredQuery:
    """Resolve type names (``label_ids`` maps base-label names to base
    ids; ``l<k>`` positional names always work) and lower the chain.

    A chain of fixed single-type hops lowers to the CPQ ``Join`` chain
    that ``repro.core.query.parse`` would produce for the same path —
    same AST, so same plans, caches and dispatch path.  Any hop with a
    variable length or a type alternation lowers the whole chain to an
    RPQ concatenation served by the fixpoint evaluator."""
    from .graph import inverse_label
    from .query import Conj, Identity

    def closure_ids(rel: Rel) -> list[int]:
        out = []
        for t in rel.types:
            base = _resolve_type(t, label_ids, n_labels)
            out.append(int(inverse_label(base, n_labels)) if rel.back
                       else base)
        return out

    named = [n for n in q.nodes if n]
    closed = (q.nodes[0] and len(q.nodes) > 1
              and q.nodes[0] == q.nodes[-1])
    interior_repeat = len(named) - len(set(named)) > (1 if closed else 0)
    if interior_repeat:
        raise UnsupportedCypher(
            "unsupported Cypher construct: repeated interior node "
            "variable — only a closed chain (first == last variable) "
            "lowers, to the identity-conjunction operator")

    pins = dict(q.pins)
    src = pins.get(q.nodes[0]) if q.nodes[0] else None
    dst = pins.get(q.nodes[-1]) if q.nodes[-1] else None

    if _is_pure_cpq(q):
        edges = [Edge(closure_ids(r)[0]) for r in q.rels]
        ast: object = edges[0]
        for e in edges[1:]:
            ast = Join(ast, e)
        if closed:
            # MATCH (a)-...->(a): the paper's q ∩ id cycle operator
            ast = Conj(ast, Identity())
        return LoweredQuery(ast=ast, src=src, dst=dst)
    if closed:
        raise UnsupportedCypher(
            "unsupported Cypher construct: cyclic variable-length "
            "chain — q ∩ id lowers only for fixed-length (CPQ) chains")

    hops: list[RPQ] = []
    for rel in q.rels:
        ids = closure_ids(rel)
        sym: RPQ = RSym(ids[0])
        for l in ids[1:]:
            sym = RAlt(sym, RSym(l))
        hops.append(_repeat(sym, rel.lo, rel.hi))
    ast = hops[0]
    for h in hops[1:]:
        ast = RConcat(ast, h)
    return LoweredQuery(ast=ast, src=src, dst=dst)


def _repeat(e: RPQ, lo: int, hi: int | None) -> RPQ:
    """``e`` repeated lo..hi times: ``e^lo`` then ``e*`` (unbounded) or
    ``(e?)^(hi-lo)`` (bounded)."""
    if hi is None:
        if lo == 0:
            return RStar(e)
        parts = [e] * (lo - 1) + [RPlus(e)]
    else:
        if hi == 0:  # *0..0 — ε-only hop, no RPQ node for bare ε
            raise UnsupportedCypher(
                "unsupported Cypher construct: zero-length "
                "relationship *0..0")
        parts = [e] * lo + [ROpt(e)] * (hi - lo)
    out = parts[0]
    for p in parts[1:]:
        out = RConcat(out, p)
    return out
