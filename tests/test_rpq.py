"""RPQ fixpoint evaluator — deterministic units + differential property.

The engine side is a Glushkov automaton driven as a semi-naive fixpoint
of per-sequence CPQx lookups (``core.rpq``); the gate is
``oracle.rpq_eval``, an *independent* Thompson ε-NFA product evaluator
(different construction, different traversal) — agreement is evidence,
not tautology.  Deterministic tests pin star termination on cyclic
graphs, the empty-frontier exit, ε semantics, the inverse/alternation
algebra, and the |Q|·|V|² pair-space termination bound; the property
tests sweep random RPQ ASTs over random graphs, locally and on the
all-devices mesh (1 device in the plain run, 8 in the CI distributed
step)."""

import jax
import numpy as np
import pytest

from repro import compat
from repro.core import index as cindex, oracle
from repro.core.engine import Engine
from repro.core.graph import LabeledGraph, inverse_label
from repro.core.query import Edge, Identity
from repro.core.rpq import (
    FixpointInfo,
    RAlt,
    RConcat,
    RInv,
    ROpt,
    RPlus,
    RStar,
    RSym,
    evaluate,
    glushkov,
    macro_edges,
    normalize,
    rpq_label_runs,
    rpq_labels,
    seq_to_cpq,
)

from conftest import random_graph


@pytest.fixture(scope="module")
def mesh1():
    """All visible devices on one 'engine' axis (1 normally; 8 in the
    CI distributed step)."""
    return compat.make_mesh((jax.device_count(),), ("engine",))


def _pairs(rows) -> set:
    return {tuple(r) for r in np.asarray(rows).reshape(-1, 2).tolist()}


def cycle_graph(n: int = 5, n_labels: int = 2) -> LabeledGraph:
    """A directed n-cycle on label 0 plus one chord on label 1 — every
    star over label 0 must saturate all n² pairs, which only happens
    after the fixpoint wraps around the cycle (> 1 iteration)."""
    edges = [(i, (i + 1) % n, 0) for i in range(n)]
    edges.append((0, n // 2, 1))
    return LabeledGraph.from_edges(n, n_labels, edges)


# ---------------------------------------------------------------------- #
# automaton construction
# ---------------------------------------------------------------------- #


class TestGlushkov:
    def test_start_state_has_no_in_edges(self):
        q = RStar(RConcat(RSym(0), RAlt(RSym(1), RPlus(RSym(0)))))
        auto = glushkov(q)
        assert all(t != 0 for _, _, t in auto.transitions)

    def test_nullable_iff_accepts_epsilon(self):
        assert glushkov(RStar(RSym(0))).nullable
        assert glushkov(ROpt(RSym(0))).nullable
        assert not glushkov(RPlus(RSym(0))).nullable
        assert not glushkov(RConcat(RSym(0), RStar(RSym(1)))).nullable
        assert glushkov(RConcat(ROpt(RSym(0)), RStar(RSym(1)))).nullable

    def test_state_count_is_positions_plus_start(self):
        q = RConcat(RSym(0), RConcat(RSym(1), RSym(0)))
        assert glushkov(q).n_states == 4  # 3 symbol occurrences + start

    def test_inverse_must_be_normalized_first(self):
        with pytest.raises(ValueError, match="normalize"):
            glushkov(RInv(RSym(0)))


class TestMacroEdges:
    def test_walks_truncated_at_k(self):
        auto = glushkov(RConcat(RSym(0), RConcat(RSym(1), RSym(0))))
        edges = macro_edges(auto, 2)
        assert all(1 <= len(seq) <= 2
                   for es in edges.values() for seq, _ in es)
        # from the start state: the length-1 walk (0,) and the length-2
        # prefix (0, 1) — truncation keeps every <= k chunk
        assert {seq for seq, _ in edges[0]} == {(0,), (0, 1)}

    def test_length_one_always_present(self):
        auto = glushkov(RStar(RSym(1)))
        edges = macro_edges(auto, 3)
        for p, es in edges.items():
            assert any(len(seq) == 1 for seq, _ in es), p

    def test_k_must_be_positive(self):
        with pytest.raises(ValueError):
            macro_edges(glushkov(RSym(0)), 0)


class TestAlgebra:
    def test_inverse_of_concat_reverses(self):
        n = 3
        got = normalize(RInv(RConcat(RSym(0), RSym(1))), n)
        want = RConcat(RSym(int(inverse_label(1, n))),
                       RSym(int(inverse_label(0, n))))
        assert got == want

    def test_inverse_distributes_over_alternation(self):
        n = 2
        got = normalize(RInv(RAlt(RSym(0), RSym(1))), n)
        assert got == RAlt(RSym(2), RSym(3))

    def test_double_inverse_is_identity(self):
        q = RStar(RConcat(RSym(0), RAlt(RSym(1), RSym(0))))
        assert normalize(RInv(RInv(q)), 2) == normalize(q, 2)

    def test_inverse_commutes_with_star(self):
        n = 2
        assert (normalize(RInv(RStar(RSym(0))), n)
                == RStar(RSym(int(inverse_label(0, n)))))

    def test_normalize_without_n_labels_raises_only_when_needed(self):
        assert normalize(RStar(RSym(0))) == RStar(RSym(0))
        with pytest.raises(ValueError, match="n_labels"):
            normalize(RInv(RSym(0)))

    def test_operator_sugar(self):
        assert RSym(0) * RSym(1) == RConcat(RSym(0), RSym(1))
        assert RSym(0) | RSym(1) == RAlt(RSym(0), RSym(1))
        assert RSym(0) * Edge(1) == RConcat(RSym(0), RSym(1))

    def test_labels_and_runs(self):
        q = RConcat(RSym(0), RConcat(RSym(1), RStar(RConcat(RSym(1),
                                                            RSym(0)))))
        assert rpq_labels(q) == {0, 1}
        assert rpq_label_runs(q) == [[0, 1], [1, 0]]


# ---------------------------------------------------------------------- #
# fixpoint evaluation — deterministic
# ---------------------------------------------------------------------- #


class TestFixpoint:
    def test_star_terminates_on_cycle_and_saturates(self):
        """Kleene star over a directed cycle: the canonical
        non-termination trap.  The fixpoint must converge (finite
        iterations within the |Q|·|V|² pair-space bound), need more than
        one iteration (the transitive closure wraps the cycle), and
        saturate every pair."""
        g = cycle_graph(5)
        eng = Engine(cindex.build(g, 2))
        info = FixpointInfo()
        rows = eng.execute_rpq(RStar(RSym(0)), info=info)
        n = g.n_vertices
        assert _pairs(rows) == {(i, j) for i in range(n) for j in range(n)}
        assert info.iterations > 1
        # the termination argument: triples live in Q × V² — both the
        # iteration count and the materialized triples obey the bound
        bound = info.states * n * n
        assert info.iterations <= bound
        assert info.triples <= bound

    def test_empty_frontier_exits_immediately(self):
        """A star over a label with no edges: the first expansion joins
        against an empty relation, the delta empties, and the loop exits
        after one round with just the ε (identity) answers."""
        g = LabeledGraph.from_edges(4, 2, [(0, 1, 0)])
        eng = Engine(cindex.build(g, 2))
        info = FixpointInfo()
        rows = eng.execute_rpq(RStar(RSym(1)), info=info)
        assert _pairs(rows) == {(v, v) for v in range(4)}
        assert info.iterations == 1

    def test_epsilon_semantics_match_identity(self, ex_graph):
        """Nullable RPQs include the identity pairs — the same relation
        ``cpq_eval(Identity)`` defines."""
        eng = Engine(cindex.build(ex_graph, 2))
        ident = oracle.cpq_eval(ex_graph, Identity())
        star = _pairs(eng.execute_rpq(RStar(RSym(0))))
        opt = _pairs(eng.execute_rpq(ROpt(RSym(0))))
        plus = _pairs(eng.execute_rpq(RPlus(RSym(0))))
        assert ident <= star and ident <= opt
        assert not ident <= plus  # 'f' has no self-loop in example_graph

    def test_plus_is_concat_star(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        a = RConcat(RSym(0), RSym(1))
        assert _pairs(eng.execute_rpq(RPlus(a))) == _pairs(
            eng.execute_rpq(RConcat(a, RStar(a))))

    def test_alternation_is_union(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        got = _pairs(eng.execute_rpq(RAlt(RSym(0), RSym(1))))
        assert got == (_pairs(eng.execute_rpq(RSym(0)))
                       | _pairs(eng.execute_rpq(RSym(1))))

    def test_inverse_transposes(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        q = RConcat(RSym(0), RStar(RSym(1)))
        fwd = _pairs(eng.execute_rpq(q))
        rev = _pairs(eng.execute_rpq(RInv(q),
                                     n_labels=ex_graph.n_labels))
        assert rev == {(u, v) for (v, u) in fwd}

    def test_source_and_dest_pins(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        q = RStar(RSym(0))
        full = _pairs(eng.execute_rpq(q))
        got = _pairs(eng.execute_rpq(q, srcs=[3, 4], dsts=[0, 1, 2]))
        assert got == {(s, d) for (s, d) in full
                       if s in (3, 4) and d in (0, 1, 2)}
        with pytest.raises(ValueError, match="out of range"):
            eng.execute_rpq(q, srcs=[99])

    def test_lookups_batched_and_cached(self, ex_graph):
        """Relations are fetched lazily in one execute_batch per round
        and cached: distinct sequences, not iterations × sequences."""
        eng = Engine(cindex.build(ex_graph, 2))
        info = FixpointInfo()
        eng.execute_rpq(RStar(RConcat(RSym(0), RSym(1))), info=info)
        assert info.lookup_batches <= info.iterations
        assert info.lookups == len({seq for es in macro_edges(
            glushkov(RStar(RConcat(RSym(0), RSym(1)))),
            2).values() for seq, _ in es})

    def test_seq_to_cpq_is_join_chain(self):
        q = seq_to_cpq((0, 1, 0))
        assert oracle.cpq_eval(cycle_graph(4), q) is not None  # evaluable
        from repro.core.query import Join
        assert q == Join(Join(Edge(0), Edge(1)), Edge(0))


# ---------------------------------------------------------------------- #
# differential: engine fixpoint == Thompson oracle
# ---------------------------------------------------------------------- #

_SHAPES = [
    RSym(0),
    RStar(RSym(0)),
    RPlus(RConcat(RSym(0), RSym(1))),
    RAlt(RSym(0), RSym(1)),
    RConcat(RSym(0), RStar(RSym(1))),
    RConcat(ROpt(RSym(0)), RPlus(RSym(1))),
    RStar(RAlt(RSym(0), RSym(1))),
    RConcat(RInv(RSym(0)), RSym(1)),
    RStar(RAlt(RSym(0), RInv(RSym(1)))),
    RInv(RStar(RConcat(RSym(0), RSym(1)))),
]


class TestDifferential:
    def test_shape_suite_example_graph(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        for q in _SHAPES:
            got = _pairs(eng.execute_rpq(q, n_labels=ex_graph.n_labels))
            assert got == oracle.rpq_eval(ex_graph, q), q

    def test_shape_suite_sharded(self, ex_graph, mesh1):
        """The same fixpoint over the sharded engine: every per-sequence
        lookup rides the mesh backend; answers must be identical to
        local and to the oracle (n_shards ∈ {1, 8} acceptance)."""
        idx = cindex.build(ex_graph, 2)
        local = Engine(idx)
        sharded = Engine(idx, mesh=mesh1)
        for q in _SHAPES:
            a = local.execute_rpq(q, n_labels=ex_graph.n_labels)
            b = sharded.execute_rpq(q, n_labels=ex_graph.n_labels)
            assert np.array_equal(a, b), q
            assert _pairs(b) == oracle.rpq_eval(ex_graph, q), q

    def test_random_graphs_deterministic(self):
        """Seeded random RPQs on seeded random graphs — the always-on
        cousin of the hypothesis property below."""
        for seed in range(6):
            g = random_graph(seed, n_max=14, n_labels=2, m_max=30)
            eng = Engine(cindex.build(g, 2))
            rng = np.random.default_rng(100 + seed)
            for _ in range(4):
                q = oracle.random_rpq(rng, g)
                info = FixpointInfo()
                got = _pairs(evaluate(eng, q, n_labels=g.n_labels,
                                      info=info))
                assert got == oracle.rpq_eval(g, q), (seed, q)
                assert info.iterations <= info.states * g.n_vertices ** 2


class TestHypothesisProperty:
    def test_engine_matches_nfa_product_oracle(self, mesh1):
        """Random RPQ ASTs on random graphs: the Glushkov fixpoint and
        the Thompson product agree, locally and on the all-devices
        mesh."""
        hypothesis = pytest.importorskip("hypothesis")
        given, settings, st = (hypothesis.given, hypothesis.settings,
                               hypothesis.strategies)

        @settings(max_examples=20, deadline=None)
        @given(gseed=st.integers(0, 2**31 - 1),
               qseed=st.integers(0, 2**31 - 1))
        def prop(gseed, qseed):
            g = random_graph(gseed, n_max=12, n_labels=2, m_max=24)
            q = oracle.random_rpq(np.random.default_rng(qseed), g)
            want = oracle.rpq_eval(g, q)
            idx = cindex.build(g, 2)
            for eng in (Engine(idx), Engine(idx, mesh=mesh1)):
                got = _pairs(eng.execute_rpq(q, n_labels=g.n_labels))
                assert got == want, q

        prop()
