from .checkpoint import (  # noqa: F401
    latest_step,
    load_checkpoint,
    restore_sharded,
    save_checkpoint,
    wait_for_writes,
)
