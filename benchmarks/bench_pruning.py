"""Paper Table III: pruning power — the number of class identifiers
(CPQx / iaCPQx) vs s-t pairs (iaPath) involved in evaluating S queries.
Smaller = stronger pruning; the paper's point is |C| << |P|."""

from __future__ import annotations

import numpy as np

from repro.core import baselines, interest
from repro.core import index as cindex
from repro.core.query import instantiate_template

from .bench_query import interests_for
from .common import DATASETS, emit


def main() -> None:
    rng = np.random.default_rng(3)
    for ds in ["robots-like", "advogato-like", "gmark-small"]:
        g = DATASETS[ds]()
        ints = interests_for(g)
        idx = cindex.build(g, 2)
        ia = interest.build_interest(g, 2, ints)
        pi = baselines.build_path(g, 2, interests=ints)
        # S queries drawn FROM the interest set (the paper evaluates
        # queries over the indexed interests)
        n_cls_cpqx, n_cls_ia, n_pairs_path, n_q = 0, 0, 0, 0
        for _ in range(5):
            s1 = ints[int(rng.integers(0, len(ints)))]
            s2 = ints[int(rng.integers(0, len(ints)))]
            for seq in (s1, s2):
                seq = tuple(int(x) for x in seq)
                lo, hi = idx.lookup_range(seq)
                n_cls_cpqx += hi - lo
                lo, hi = ia.lookup_range(seq)
                n_cls_ia += hi - lo
                lo, hi = pi.lookup_range(seq)
                n_pairs_path += hi - lo
            n_q += 1
        emit(f"table3/{ds}/CPQx_classes", n_cls_cpqx / n_q, "avg per S query")
        emit(f"table3/{ds}/iaCPQx_classes", n_cls_ia / n_q, "avg per S query")
        emit(f"table3/{ds}/iaPath_pairs", n_pairs_path / n_q, "avg per S query")
        # the paper's Table III comparison: ia classes <= ia path pairs
        assert n_cls_ia <= n_pairs_path + 1e-9


if __name__ == "__main__":
    main()
