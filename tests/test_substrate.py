"""Substrate tests: optimizer, schedules, train loop (incl. resume +
NaN breaker), checkpointing (atomic/async/elastic), data generators,
neighbor sampler, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, save_checkpoint,
                              wait_for_writes)
from repro.data.graphs import gmark_citation, powerlaw_graph
from repro.data.sampler import random_csr, sample_fanout
from repro.data.tokens import TokenStream
from repro.train import compress
from repro.train.loop import StragglerStats, TrainConfig, make_train_step, train
from repro.train.optim import adamw_init, adamw_update, global_norm
from repro.train.schedules import cosine, wsd


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(g, opt, params, lr=0.1,
                                          weight_decay=0.0)
        assert float(loss(params)) < 1e-2

    def test_clip(self):
        params = {"w": jnp.ones(4)}
        opt = adamw_init(params)
        g = {"w": jnp.full(4, 1e6)}
        _, _, m = adamw_update(g, opt, params, lr=0.1, clip_norm=1.0)
        assert float(m["clip_scale"]) < 1e-5


class TestSchedules:
    def test_cosine_shape(self):
        lrs = [float(cosine(s, peak_lr=1.0, warmup=10, total=100))
               for s in range(100)]
        assert lrs[0] == 0.0
        assert abs(lrs[10] - 1.0) < 0.05
        assert lrs[99] < 0.2
        assert all(a >= b - 1e-6 for a, b in zip(lrs[10:], lrs[11:]))

    def test_wsd_plateau(self):
        lrs = [float(wsd(s, peak_lr=1.0, warmup=10, stable=70, decay=20))
               for s in range(100)]
        assert abs(lrs[40] - 1.0) < 1e-6  # stable plateau
        assert abs(lrs[75] - 1.0) < 1e-6
        assert lrs[99] < 0.1  # decayed


class TestTrainLoop:
    def _setup(self):
        def loss_fn(p, batch):
            x, y = batch
            pred = x @ p["w"]
            return jnp.mean((pred - y) ** 2), {}

        rng = np.random.default_rng(0)
        w_true = rng.normal(0, 1, (4, 1)).astype(np.float32)

        def data_at(step):
            r = np.random.default_rng(step)
            x = r.normal(0, 1, (16, 4)).astype(np.float32)
            return jnp.asarray(x), jnp.asarray(x @ w_true)

        params = {"w": jnp.zeros((4, 1))}
        return loss_fn, params, data_at

    def test_loss_decreases(self):
        loss_fn, params, data_at = self._setup()
        tcfg = TrainConfig(steps=60, peak_lr=0.05, warmup=5)
        _, _, hist = train(loss_fn, params, data_at, tcfg)
        assert hist[-1]["loss"] < hist[0]["loss"] * 0.1

    def test_resume_is_deterministic(self, tmp_path):
        loss_fn, params, data_at = self._setup()
        tcfg = TrainConfig(steps=30, peak_lr=0.05, warmup=5,
                           ckpt_dir=str(tmp_path), ckpt_every=10)
        p1, o1, h1 = train(loss_fn, params, data_at, tcfg)
        wait_for_writes()
        # resume from step 20 and rerun the tail
        from repro.checkpoint import restore_sharded

        like = {"params": params, "opt": adamw_init(params)}
        restored = restore_sharded(str(tmp_path), 20, like)
        p2, o2, h2 = train(loss_fn, restored["params"], data_at,
                           TrainConfig(steps=30, peak_lr=0.05, warmup=5),
                           start_step=20, opt_state=restored["opt"])
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   rtol=1e-5)

    def test_nan_breaker(self):
        def loss_fn(p, batch):
            return jnp.float32(np.nan) * jnp.sum(p["w"]), {}

        params = {"w": jnp.ones(2)}
        tcfg = TrainConfig(steps=20, peak_lr=0.1, warmup=1, max_bad_steps=3)
        with pytest.raises(FloatingPointError):
            train(loss_fn, params, lambda s: (jnp.zeros(1), jnp.zeros(1)),
                  tcfg)

    def test_straggler_detection(self):
        st = StragglerStats()
        for _ in range(10):
            st.observe(0.1, 3.0)
        assert st.observe(10.0, 3.0)  # 100x the EWMA
        assert st.n_stragglers == 1

    def test_grad_accumulation_matches_large_batch(self):
        loss_fn, params, data_at = self._setup()
        x, y = data_at(0)
        step1 = jax.jit(make_train_step(loss_fn, TrainConfig(steps=10,
                                                             accum=1)))
        step2 = jax.jit(make_train_step(loss_fn, TrainConfig(steps=10,
                                                             accum=4)))
        opt = adamw_init(params)
        p1, _, _ = step1(params, opt, (x, y), jnp.int32(5))
        xs = x.reshape(4, 4, 4)
        ys = y.reshape(4, 4, 1)
        p2, _, _ = step2(params, opt, (xs, ys), jnp.int32(5))
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   rtol=1e-4)


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        back = load_checkpoint(str(tmp_path), 7, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        assert back["b"]["c"].dtype == jnp.bfloat16

    def test_async_then_wait(self, tmp_path):
        tree = {"x": jnp.ones((128, 128))}
        save_checkpoint(str(tmp_path), 1, tree, async_write=True)
        wait_for_writes()
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(4)})
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, {"x": jnp.ones(5)})

    def test_no_partial_commit(self, tmp_path):
        """A .tmp directory must never be visible as a committed step."""
        save_checkpoint(str(tmp_path), 3, {"x": jnp.ones(2)})
        names = os.listdir(tmp_path)
        assert "step_000000003" in names
        assert not any(n.endswith(".tmp") for n in names)


class TestData:
    def test_token_stream_deterministic(self):
        s = TokenStream(100, 4, 16, seed=1)
        a1, b1 = s.batch_at(5)
        a2, b2 = s.batch_at(5)
        np.testing.assert_array_equal(a1, a2)
        # shard slices tile the global batch
        rows = [s.shard_at(5, i, 4)[0] for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(rows, 0), a1)

    def test_gmark_schema_roles(self):
        g = gmark_citation(200, seed=0)
        assert g.n_labels == 6
        # heldIn (label 5) goes venue -> city only
        m = g.lbl == 5
        assert (g.src[m] >= 160).all() and (g.dst[m] >= 190).all()

    def test_powerlaw_label_distribution(self):
        g = powerlaw_graph(500, 4000, n_labels=8, seed=0)
        base = g.lbl[g.lbl < 8]
        counts = np.bincount(base, minlength=8)
        assert counts[0] > counts[2] > counts[5]  # exponentially decaying

    def test_fanout_sampler(self):
        g = random_csr(1000, avg_degree=12, seed=0)
        seeds = np.arange(8)
        sub = sample_fanout(g, seeds, (4, 3), seed=1)
        assert sub.node_ids.shape[0] == 8 + 32 + 96
        assert sub.senders.shape[0] == 32 + 96
        # every masked edge points at a real node
        for s, r, ok in zip(sub.senders, sub.receivers, sub.edge_mask):
            if ok:
                assert sub.node_ids[s] >= 0 and sub.node_ids[r] >= 0


class TestCompression:
    def test_quantize_error_feedback(self):
        rng = np.random.default_rng(0)
        g = jnp.array(rng.normal(0, 1, (1000,)), jnp.float32)
        res = jnp.zeros_like(g)
        (q, scale, n), new_res = compress.quantize_with_feedback(g, res)
        approx = compress._dequantize(q, scale, n, g.shape)
        # int8 blockwise: < 1% relative error per block
        assert float(jnp.linalg.norm(approx - g) / jnp.linalg.norm(g)) < 0.01
        # residual carries the quantization error exactly
        np.testing.assert_allclose(np.asarray(new_res),
                                   np.asarray(g - approx), atol=1e-7)

    def test_error_feedback_converges(self):
        """Repeated compressed accumulation of the same gradient converges
        to the true sum (EF property)."""
        rng = np.random.default_rng(1)
        g = jnp.array(rng.normal(0, 1, (512,)), jnp.float32)
        res = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(50):
            (q, scale, n), res = compress.quantize_with_feedback(g, res)
            total = total + compress._dequantize(q, scale, n, g.shape)
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=2e-3)
