"""BST — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874].

    item/category embeddings + learned positions over a length-20 behavior
    sequence (target item appended) -> 1 transformer block (8 heads)
    -> concat with user/context EmbeddingBag features -> MLP 1024-512-256
    -> logit.

JAX has no ``nn.EmbeddingBag``: multi-hot context features are reduced
with ``jnp.take`` + ``jax.ops.segment_sum`` — that lookup-reduce IS the
hot path at recsys batch sizes, so it is implemented here as part of the
system (see kernel_taxonomy §RecSys), not stubbed.

Shapes (assigned):
    train_batch   B=65,536 train_step
    serve_p99     B=512    serve_step
    serve_bulk    B=262,144 serve_step
    retrieval_cand B=1, 1M candidates: two-stage scoring — sequence tower
    runs once, candidate embeddings scored with a batched dot + MLP-lite
    head (no per-candidate transformer), then distributed top-k.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 4_000_000  # sparse table rows (Alibaba-scale surrogate)
    n_cats: int = 100_000
    n_context: int = 1_000_000  # multi-hot context vocab (user profile etc.)
    embed_dim: int = 32
    seq_len: int = 20  # behavior sequence incl. target slot
    n_heads: int = 8
    n_blocks: int = 1
    d_ff: int = 128
    mlp_dims: tuple = (1024, 512, 256)
    n_context_fields: int = 8  # avg multi-hot ids per example
    param_dtype: str = "float32"

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)


class BSTBatch(NamedTuple):
    """One ranking batch.

    item_ids   (B, S)  behavior sequence, target at slot S-1
    cat_ids    (B, S)
    ctx_ids    (B*F,)  flattened multi-hot context ids
    ctx_segs   (B*F,)  example id per context id (EmbeddingBag segments)
    labels     (B,)    click labels (train only)
    """

    item_ids: jax.Array
    cat_ids: jax.Array
    ctx_ids: jax.Array
    ctx_segs: jax.Array
    labels: jax.Array


def init_params(cfg: BSTConfig, key) -> dict:
    dt = cfg.dtype
    d = cfg.embed_dim
    ks = jax.random.split(key, 16)

    def emb(k, n, dim):
        return (jax.random.normal(k, (n, dim), jnp.float32) * 0.01).astype(dt)

    def lin(k, i, o):
        return (jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i)).astype(dt)

    dm = 2 * d  # item ⊕ category per position
    blocks = []
    for bi in range(cfg.n_blocks):
        bk = jax.random.split(ks[6 + bi], 8)
        blocks.append({
            "wq": lin(bk[0], dm, dm), "wk": lin(bk[1], dm, dm),
            "wv": lin(bk[2], dm, dm), "wo": lin(bk[3], dm, dm),
            "ff1": lin(bk[4], dm, cfg.d_ff), "ff2": lin(bk[5], cfg.d_ff, dm),
            "ln1": jnp.ones((dm,), dt), "ln2": jnp.ones((dm,), dt),
        })
    mlp_in = cfg.seq_len * dm + d  # flattened sequence + context bag
    dims = (mlp_in,) + cfg.mlp_dims + (1,)
    mlp = {
        f"w{i}": lin(jax.random.fold_in(ks[5], i), dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    } | {f"b{i}": jnp.zeros((dims[i + 1],), dt) for i in range(len(dims) - 1)}
    return {
        "item_emb": emb(ks[0], cfg.n_items, d),
        "cat_emb": emb(ks[1], cfg.n_cats, d),
        "ctx_emb": emb(ks[2], cfg.n_context, d),
        "pos_emb": emb(ks[3], cfg.seq_len, dm),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "mlp": mlp,
    }


def embedding_bag(table: jax.Array, ids: jax.Array, segs: jax.Array,
                  n_segments: int, mode: str = "sum") -> jax.Array:
    """EmbeddingBag via take + segment_sum (the JAX-native lowering)."""
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, segs, n_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, table.dtype), segs,
                                  n_segments)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _ln(x, w, eps=1e-6):
    m = jnp.mean(x, -1, keepdims=True)
    v = jnp.var(x, -1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps) * w


def _transformer_block(bp, x, n_heads):
    b, s, dm = x.shape
    hd = dm // n_heads
    h = _ln(x, bp["ln1"])
    q = (h @ bp["wq"]).reshape(b, s, n_heads, hd)
    k = (h @ bp["wk"]).reshape(b, s, n_heads, hd)
    v = (h @ bp["wv"]).reshape(b, s, n_heads, hd)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(hd)
    p = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    att = jnp.einsum("bhst,bthd->bshd", p, v).reshape(b, s, dm)
    x = x + att @ bp["wo"]
    h = _ln(x, bp["ln2"])
    x = x + jax.nn.leaky_relu(h @ bp["ff1"]) @ bp["ff2"]
    return x


def sequence_tower(cfg: BSTConfig, params: dict, item_ids, cat_ids):
    """(B, S) ids -> (B, S*2d) transformer-encoded sequence features."""
    e = jnp.concatenate(
        [jnp.take(params["item_emb"], item_ids, 0),
         jnp.take(params["cat_emb"], cat_ids, 0)], -1)  # (B,S,2d)
    x = e + params["pos_emb"][None]

    def body(x, bp):
        return _transformer_block(bp, x, cfg.n_heads), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    b = x.shape[0]
    return x.reshape(b, -1)


def forward(cfg: BSTConfig, params: dict, batch: BSTBatch) -> jax.Array:
    """CTR logits (B,)."""
    b = batch.item_ids.shape[0]
    seq = sequence_tower(cfg, params, batch.item_ids, batch.cat_ids)
    ctx = embedding_bag(params["ctx_emb"], batch.ctx_ids, batch.ctx_segs, b)
    x = jnp.concatenate([seq, ctx], -1)
    n = len(cfg.mlp_dims) + 1
    for i in range(n):
        x = x @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
        if i < n - 1:
            x = jax.nn.leaky_relu(x)
    return x[:, 0].astype(jnp.float32)


def train_loss(cfg: BSTConfig, params: dict, batch: BSTBatch):
    logits = forward(cfg, params, batch)
    y = batch.labels.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"bce": loss}


# ---------------------------------------------------------------------- #
# retrieval: score 1M candidates for one user — batched dot, not a loop
# ---------------------------------------------------------------------- #


def retrieval_scores(cfg: BSTConfig, params: dict, item_ids, cat_ids,
                     ctx_ids, ctx_segs, cand_ids) -> jax.Array:
    """(n_cand,) scores: user tower output dotted with candidate item
    embeddings (two-tower approximation of BST scoring for retrieval;
    the full MLP head reranks the top-k downstream)."""
    seq = sequence_tower(cfg, params, item_ids, cat_ids)  # (1, S*2d)
    ctx = embedding_bag(params["ctx_emb"], ctx_ids, ctx_segs, 1)  # (1, d)
    user = jnp.concatenate([seq, ctx], -1)  # (1, D)
    # project user to embed_dim with the first MLP layer slice (cheap head)
    w = params["mlp"]["w0"][:, : cfg.embed_dim]  # (D, d)
    u = jax.nn.tanh(user @ w)  # (1, d)
    cand = jnp.take(params["item_emb"], cand_ids, 0)  # (n_cand, d)
    return (cand @ u[0]).astype(jnp.float32)


def retrieval_topk(cfg: BSTConfig, params: dict, item_ids, cat_ids, ctx_ids,
                   ctx_segs, cand_ids, k: int = 100):
    scores = retrieval_scores(cfg, params, item_ids, cat_ids, ctx_ids,
                              ctx_segs, cand_ids)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, jnp.take(cand_ids, idx, 0)
