"""Per-kernel microbenchmarks: Pallas (interpret on CPU / compiled on
TPU) vs the jnp reference path, across the engine's working sizes.
On CPU the relative numbers reflect interpret-mode overhead — the
correctness contract is what CI checks; on TPU this bench reports the
fusion win.

Also sweeps the autotuner's block-shape candidates per capacity rung
(``kernels.autotune``) and emits one row per (kernel, rung, block), so
the block-shape landscape lands in the ``BENCH_*.json`` trajectory like
every other bench (``--json``, or via ``benchmarks.run``)."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.autotune import autotune

from .common import emit, timeit, write_json


def block_sweep_section(rungs, repeats: int) -> None:
    """One row per candidate block shape at each capacity rung — the
    same sweep the calibration artifact caches winners from."""
    block_q, block_t, raw = autotune(rungs, repeats=repeats)
    for (kind, rung, blk), ns in sorted(raw.items()):
        win = (block_q if kind == "block_q" else block_t)[rung]
        emit(f"kernels/sweep/{kind}/r{rung}/b{blk}", ns / 1e3,
             f"winner={win};chosen={blk == win}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller block-sweep rungs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    args, _ = ap.parse_known_args()
    rng = np.random.default_rng(0)

    # sorted_intersect: class-id membership at paper-ish sizes
    for n_hay, n_q in [(1 << 10, 1 << 12), (1 << 14, 1 << 14)]:
        hay = np.sort(rng.choice(n_hay * 8, n_hay, replace=False)).astype(np.int32)
        q = rng.integers(0, n_hay * 8, n_q).astype(np.int32)
        hj, qj = jnp.asarray(hay), jnp.asarray(q)
        f_k = jax.jit(lambda h, q: ops.sorted_member_mask(h, n_hay, q))
        f_r = jax.jit(lambda h, q: ref.sorted_member_mask(h, n_hay, q))
        f_k(hj, qj).block_until_ready()
        f_r(hj, qj).block_until_ready()
        emit(f"kernels/sorted_intersect/{n_hay}x{n_q}/pallas",
             timeit(lambda: f_k(hj, qj).block_until_ready()), "")
        emit(f"kernels/sorted_intersect/{n_hay}x{n_q}/jnp_ref",
             timeit(lambda: f_r(hj, qj).block_until_ready()), "")

    # fingerprint: 2-column mix at build sizes
    n = 1 << 15
    cols = tuple(jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
                 for _ in range(2))
    f_k = jax.jit(lambda a, b: ops.fingerprint_rows((a, b), 3))
    f_r = jax.jit(lambda a, b: ref.fingerprint_rows((a, b), 3))
    jax.block_until_ready(f_k(*cols))
    jax.block_until_ready(f_r(*cols))
    emit(f"kernels/fingerprint/{n}/pallas",
         timeit(lambda: jax.block_until_ready(f_k(*cols))), "")
    emit(f"kernels/fingerprint/{n}/jnp_ref",
         timeit(lambda: jax.block_until_ready(f_r(*cols))), "")

    # segment_softmax at GNN edge sizes
    e, d, nseg = 1 << 14, 8, 1 << 10
    scores = jnp.asarray(rng.normal(0, 1, (e, d)).astype(np.float32))
    seg = jnp.asarray(np.sort(rng.integers(0, nseg, e)).astype(np.int32))
    f_k = jax.jit(lambda s, g: ops.segment_softmax(s, g, nseg))
    f_r = jax.jit(lambda s, g: ref.segment_softmax(s, g, nseg))
    f_k(scores, seg).block_until_ready()
    f_r(scores, seg).block_until_ready()
    emit(f"kernels/segment_softmax/{e}x{d}/pallas",
         timeit(lambda: f_k(scores, seg).block_until_ready()), "")
    emit(f"kernels/segment_softmax/{e}x{d}/jnp_ref",
         timeit(lambda: f_r(scores, seg).block_until_ready()), "")

    block_sweep_section(rungs=(1 << 10,) if args.smoke
                        else (1 << 10, 1 << 12, 1 << 14),
                        repeats=2 if args.smoke else 3)
    jax.clear_caches()

    if args.json:
        write_json(args.json, bench="bench_kernels", smoke=args.smoke)


if __name__ == "__main__":
    main()
