"""Adaptive iaCPQx under a drifting workload — the PR 5 adaptation gate.

Three engines serve the same drifting query stream
(:func:`repro.data.graphs.drifting_workload` over
:data:`benchmarks.common.ADAPTIVE_PHASES`):

  adaptive  a ``QueryService`` over an interest-aware mirror that starts
            with NO mined interests and closes the loop itself
            (``core.workload``: sketch -> benefit -> coalesced interest
            updates through the write path);
  static    the same initial index, never adapted — the "interest set is
            given up front" baseline the paper assumes (Sec. V);
  full      full CPQx — the latency target the adapted index should
            converge toward at a fraction of its size.

Per phase the stream is served through the adaptive service (adaptation
rounds fire automatically from traffic), then a checkpoint times every
hot template on all three engines and gates on answers:
``adaptive == static == full == numpy oracle`` — a FAIL exits non-zero.
In ``--smoke`` (CI) mode each phase must also show >= 2x speedup on at
least one hot template (adaptive vs static), the drift phase included —
i.e. the loop must both MINE the new hot sequences and EVICT the stale
ones under its budget — and the final mined index must stay under half
of full CPQx's entry count.  Ladder telemetry (retry rungs per engine)
is emitted alongside wall-clock so estimator/adaptation wins stay
visible in the perf-trajectory JSON.

    PYTHONPATH=src python -m benchmarks.bench_adaptive [--smoke] [--json out.json]
"""

from __future__ import annotations

import argparse
import sys

from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import instantiate_template
from repro.core.service import QueryService
from repro.core.workload import AdaptationConfig, AdaptationController
from repro.data.graphs import drifting_workload

from .common import ADAPTIVE_PHASES, DATASETS, emit, timeit


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


def _mined(mi: MaintainableIndex) -> list:
    return sorted(s for s in mi.index.interests if len(s) >= 2)


def bench_adaptive(ds: str, n_per_phase: int, adapt_interval: int,
                   iters: int, gate_speedup: bool) -> bool:
    g = DATASETS[ds]()
    k = 2

    mi = MaintainableIndex.build(g, k, interests=[])
    adapter = AdaptationController(
        k, config=AdaptationConfig(budget=2, min_count=3.0, dwell=1,
                                   swap_margin=2.0, decay=0.5))
    svc = QueryService(Engine(mi.flush()), maintainer=mi, adapter=adapter,
                       adapt_interval=adapt_interval, max_batch=16)
    static_engine = Engine(MaintainableIndex.build(g, k, interests=[]).flush())
    full_idx = cindex.build(g, k)
    full_engine = Engine(full_idx)

    stream = drifting_workload(g, ADAPTIVE_PHASES, n_per_phase, seed=11)
    failed = False
    for pi, (queries, hot) in enumerate(zip(stream, ADAPTIVE_PHASES)):
        t0_rungs = svc.engine.telemetry.retry_rungs
        us_serve = timeit(lambda: [svc.query(q) for q in queries],
                          warmup=0, iters=1) / max(1, len(queries))
        svc.flush()  # drain any adaptation ops proposed on the last tick
        mined = _mined(mi)
        emit(f"adaptive/{ds}/phase{pi}/serve", us_serve,
             f"n_queries={len(queries)};mined={mined};"
             f"adapt_rounds={svc.stats.adapt_rounds};"
             f"rungs={svc.engine.telemetry.retry_rungs - t0_rungs}")

        wins = 0
        for name, labels in hot:
            q = instantiate_template(name, list(labels))
            truth = oracle.cpq_eval(g, q)
            got_a = _rows(svc.engine.execute(q))
            got_s = _rows(static_engine.execute(q))
            got_f = _rows(full_engine.execute(q))
            ok = got_a == got_s == got_f == truth
            failed |= not ok

            def rungs_of(engine, fn):
                before = engine.telemetry.retry_rungs
                us = timeit(fn, iters=iters)
                return us, engine.telemetry.retry_rungs - before

            us_a, r_a = rungs_of(svc.engine, lambda: svc.engine.execute(q))
            us_s, r_s = rungs_of(static_engine,
                                 lambda: static_engine.execute(q))
            us_f, r_f = rungs_of(full_engine, lambda: full_engine.execute(q))
            speedup = us_s / max(us_a, 1e-9)
            if ok and speedup >= 2.0:
                wins += 1
            emit(f"adaptive/{ds}/phase{pi}/{name}", us_a,
                 f"static_us={us_s:.1f};full_us={us_f:.1f};"
                 f"speedup_vs_static={speedup:.2f}x;"
                 f"vs_full={us_a / max(us_f, 1e-9):.2f}x;"
                 f"rungs={r_a}/{r_s}/{r_f};"
                 f"n_rows={len(truth)};"
                 f"answers={'PASS' if ok else 'FAIL'}")
        verdict = "PASS" if (wins >= 1 and not failed) else "FAIL"
        emit(f"adaptive/{ds}/phase{pi}/acceptance", 0.0,
             f"ge2x_wins={wins}/{len(hot)};"
             f"answers==static==full==oracle;{verdict}")
        failed |= gate_speedup and wins < 1

    a_l2c, a_pairs = svc.engine.index.size_entries()
    f_l2c, f_pairs = full_idx.size_entries()
    frac = (a_l2c + a_pairs) / max(1, f_l2c + f_pairs)
    emit(f"adaptive/{ds}/size", float(a_l2c + a_pairs),
         f"full={f_l2c + f_pairs};fraction={frac:.3f};"
         f"mined={_mined(mi)};"
         f"inserted={svc.stats.interests_inserted};"
         f"deleted={svc.stats.interests_deleted}")
    if gate_speedup and frac > 0.5:
        emit(f"adaptive/{ds}/size/acceptance", 0.0,
             f"fraction={frac:.3f}>0.5;FAIL")
        failed = True
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small graph, speedup + size gates on")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    args, _ = ap.parse_known_args()

    if args.smoke:
        failed = bench_adaptive("skewed-hub-small", n_per_phase=60,
                                adapt_interval=15, iters=2,
                                gate_speedup=True)
    else:
        failed = bench_adaptive("skewed-hub", n_per_phase=120,
                                adapt_interval=20, iters=3,
                                gate_speedup=False)
    if args.json:
        from .common import write_json

        write_json(args.json, bench="bench_adaptive", smoke=args.smoke)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
