"""RPQ fixpoint serving (PR 9): Cypher-subset queries lowered to
automaton fixpoints of per-sequence CPQx lookups.

Workload: variable-length/alternation path queries (openCypher text,
``l<k>`` positional types) lowered by ``core.cypher`` — pure-CPQ shapes
ride ``Engine.execute``, the rest run as Glushkov fixpoints through
``Engine.execute_rpq``.  Reported per query: wall time, fixpoint
iterations, distinct per-sequence lookups and dispatch rounds
(``FixpointInfo``).

Correctness gates (the bench fails, not just reports):

* every query — CPQ or RPQ — must equal the independent Thompson
  NFA-product oracle (``oracle.rpq_eval`` / ``oracle.cpq_eval``);
* at least one star query must converge in **more than one** fixpoint
  iteration (a 1-iteration star means the workload never exercised the
  semi-naive loop — the bench would be vacuous);
* every fixpoint must respect the |Q|·|V|² iteration bound.

    PYTHONPATH=src python -m benchmarks.bench_rpq [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import index as cindex, oracle
from repro.core.cypher import lower_cypher, parse_cypher
from repro.core.engine import Engine
from repro.core.rpq import FixpointInfo
from repro.core.service import QueryService

from .common import DATASETS, emit, write_json

# Cypher-subset workload over positional types (dataset-agnostic; every
# DATASETS graph has >= 2 base labels).  Star shapes first — they drive
# the fixpoint loop; the tail shapes cover alternation, inverse
# direction, bounded repeats and the pure-CPQ lowering path.
WORKLOAD = [
    "MATCH (a)-[:l0*]->(b) RETURN a, b",
    "MATCH (a)-[:l0*0..]->(b) RETURN a, b",
    "MATCH (a)-[:l0|l1*]->(b) RETURN a, b",
    "MATCH (a)<-[:l0*1..3]-(b) RETURN a, b",
    "MATCH (a)-[:l0]->(b)-[:l1*0..]->(c) RETURN a, c",
    "MATCH (a)-[:l0*2..3]->(b)-[:l1]->(c) RETURN a, c",
    "MATCH (a)-[:l0]->(b)-[:l1]->(c) RETURN a, c",  # pure CPQ
]


def _pairs(rows) -> set:
    return {tuple(r) for r in np.asarray(rows).reshape(-1, 2).tolist()}


def run_dataset(ds: str, iters: int) -> None:
    g = DATASETS[ds]()
    engine = Engine(cindex.build(g, 2))
    svc = QueryService(engine, max_batch=len(WORKLOAD))

    star_multi_iter = 0
    for text in WORKLOAD:
        low = lower_cypher(parse_cypher(text), None, g.n_labels)
        tag = "cpq" if low.is_cpq else "rpq"
        info = FixpointInfo()

        if low.is_cpq:
            run = lambda q=low.ast: engine.execute(q)
            want = oracle.cpq_eval(g, low.ast)
            rows = run()  # warmup: compile
        else:
            run = lambda q=low.ast: engine.execute_rpq(q)
            want = oracle.rpq_eval(g, low.ast)
            # warmup (compile + relation fetch) doubles as the telemetry
            # run — one fixpoint's counters, not warmup + iters summed
            rows = engine.execute_rpq(low.ast, info=info)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            run()
            ts.append(time.perf_counter() - t0)
        us = float(np.min(ts)) * 1e6

        # -------- gates ------------------------------------------------ #
        assert _pairs(rows) == want, f"engine != oracle: {text}"
        derived = f"kind={tag};answers={len(want)}"
        if not low.is_cpq:
            bound = info.states * g.n_vertices ** 2
            assert info.iterations <= bound, f"bound exceeded: {text}"
            derived += (f";iters={info.iterations};lookups={info.lookups}"
                        f";batches={info.lookup_batches}"
                        f";macro_edges={info.macro_edges}")
            if "*]" in text or "*0..]" in text:
                star_multi_iter = max(star_multi_iter, info.iterations)

        # the serving path must agree with the direct path (RPQs ride
        # the same (epoch, query) cache and drain rounds as CPQs)
        req = svc.submit(low.ast)
        if not req.done:
            svc.flush()
        assert _pairs(req.result) == want, f"service != oracle: {text}"

        emit(f"rpq/{ds}/{text[:40].replace(',', ';')}", us, derived)

    assert star_multi_iter > 1, (
        "no star query needed more than one fixpoint iteration — the "
        "workload never exercised the semi-naive loop")
    emit(f"rpq/{ds}/acceptance", 0.0,
         f"oracle=PASS;star_iters={star_multi_iter};served={len(WORKLOAD)}")
    jax.clear_caches()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="example graph only, minimal iterations (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="serialize emitted rows (CI artifact)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        run_dataset("example", iters=1)
    else:
        for ds in ("example", "gmark-small"):
            run_dataset(ds, iters=5)
    if args.json:
        write_json(args.json, bench="rpq", smoke=args.smoke)


if __name__ == "__main__":
    main()
