"""End-to-end LM training driver (deliverable (b)): trains a ~100M-param
MiniCPM-style model for a few hundred steps with the WSD schedule,
checkpointing, resume, and straggler logging.

    PYTHONPATH=src python examples/train_lm.py --steps 200

This wraps launch/train.py with the "~100M for a few hundred steps"
configuration the assignment asks for; on CPU expect a few minutes.
Use --tiny for a seconds-long smoke run.
"""

import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "minicpm-2b",
        "--schedule", "wsd",
        "--ckpt-dir", args.ckpt_dir,
        "--resume",
    ]
    if args.tiny:
        cmd += ["--steps", "30", "--batch", "4", "--seq", "64", "--scale", "1"]
    else:
        # ~100M params: smoke config widened 4x, batch 8 x 256 tokens
        cmd += ["--steps", str(args.steps), "--batch", "8", "--seq", "256",
                "--scale", "4"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
