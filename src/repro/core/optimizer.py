"""Cost-based CPQ query optimizer — statistics-aware planning.

``core.query.plan_query`` is purely *syntactic*: it splits label chains
greedily left-to-right and keeps operands in source order.  Which side of
a join expands first and which LOOKUP a conjunction probes decides
whether CPQx prunes by orders of magnitude or degenerates toward the
baseline (Sec. IV-D/VI), so this module re-plans with the exact
cardinalities the index already holds (:class:`repro.core.stats.
IndexStats` — class-list lengths from ``I_l2c``, per-class pair counts
from the ``I_c2p`` CSR offsets):

* **segment splits** — a label chain is split into the valid <= k
  segmentation with the cheapest estimated evaluation, enumerated among
  all compositions (bounded; greedy fallback past
  :data:`MAX_SPLIT_ENUM`), not just the greedy longest-prefix one.  A
  run that fits one indexed segment is always taken whole: its
  materialization *is* the answer, so no split can beat it.
* **conjunction ordering** — CONJ is commutative; operands are ordered
  smallest-estimate-first so the sorted-intersect kernel probes the
  small side and intermediate caps track the selective operand.
* **join association** — composition is associative; flattened join
  chains are re-associated by an interval DP (matrix-chain style) over
  estimated intermediate sizes, choosing which side of every join is
  built versus probed by estimated output size.

The optimizer emits plans in the *same* nested-tuple language as
``plan_query`` — backends, the plan walker, ``plan_shape`` and the
serving layer are untouched; ``plan_query`` remains the stats-free
fallback (the numpy oracle keeps using it, so differential tests stay
independent of this module).  Cardinality estimates are exact for
LOOKUP leaves and conservative upper bounds for conjunctions; joins use
the classic distinct-value estimate |A|·|B| / max(V(A.t), V(B.s)) with
the exact per-sequence endpoint statistics of
:meth:`~repro.core.stats.IndexStats.seq_endpoints`, capped by the sound
fanout bounds |A.t|·max_out(B) and |B.s|·max_in(A) — and degrade to the
uniform |A|·|B| / |V| guess when a view has no pair columns.  A
misestimate can never change answers — only capacities — because every
plan still runs under the sticky-overflow double-and-retry ladder (see
``core.backend``).

Host-side only: no jax import.
"""

from __future__ import annotations

import dataclasses

from .query import (
    CPQ,
    Conj,
    Edge,
    Identity,
    Join,
    _flatten_join,
    _split_seq,
    _strip_identity_joins,
    freeze_plan,
)
from .stats import IndexStats

#: Split-enumeration budget per label run; runs with more valid
#: compositions fall back to the greedy split (correctness unaffected).
MAX_SPLIT_ENUM = 256


# ---------------------------------------------------------------------- #
# cost model
# ---------------------------------------------------------------------- #


_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class PlanEstimate:
    """Estimated execution profile of one physical plan (or sub-plan).

    ``classes``  — class-list length if the result can stay in class
                   space (None once pairs materialize);
    ``pairs``    — cardinality of the result once materialized;
    ``cost``     — total rows touched (the optimizer's objective);
    ``max_pairs``— largest pair-space relation materialized anywhere
                   (drives ``QueryCaps.pair_cap``);
    ``max_join`` — largest pre-dedup expansion-join output (drives
                   ``QueryCaps.join_cap``);
    ``d_src`` / ``d_dst`` — estimated distinct source/target endpoints
                   (exact at LOOKUP leaves with endpoint statistics, else
                   the uniform |V| assumption — which recovers the
                   classic |A|·|B| / |V| join estimate verbatim);
    ``max_out`` / ``max_in`` — out/in fanout upper bound of the result
                   (inf when unknown);
    ``cost_ns``  — estimated device time: the row estimates priced
                   through a :class:`~repro.core.costmodel.
                   DeviceCostTable`'s per-operator affine stage constants
                   (fixed dispatch cost + per-row cost per plan stage).
                   Exactly 0.0 when no table was supplied — the pure
                   row-count ``cost`` is then the only objective, which
                   keeps every pre-table golden plan byte-identical.
    """

    classes: float | None
    pairs: float
    cost: float
    max_pairs: float
    max_join: float
    d_src: float = _INF
    d_dst: float = _INF
    max_out: float = _INF
    max_in: float = _INF
    cost_ns: float = 0.0


def _ns(table, op: str, rows: float) -> float:
    """Price one plan stage against the cost table; 0.0 with no table
    (the row-count objective then decides alone, exactly as pre-table)."""
    if table is None:
        return 0.0
    return table.stage_ns(op, rows)


def join_card(a: float, b: float, n_vertices: int) -> float:
    """Uniform-endpoint composition estimate: |A ∘ B| ≈ |A|·|B| / |V|,
    clamped to [1, |A|·|B|]; exactly 0 when either side is empty.  The
    stats-free fallback of :func:`join_est` (and the form the pre-PR-5
    cost model used everywhere)."""
    if a <= 0 or b <= 0:
        return 0.0
    return min(a * b, max(1.0, a * b / max(1, n_vertices)))


def join_est(el: "PlanEstimate", er: "PlanEstimate",
             n_vertices: int) -> "PlanEstimate":
    """Endpoint-aware composition estimate, as a composed profile.

    Cardinality is the distinct-value estimate |A|·|B| / max(V(A.t),
    V(B.s)) — exactly |A|·|B| / |V| when endpoint statistics are absent
    (both distinct counts default to |V|) — capped by the *sound* upper
    bounds on the result: every A pair expands through at most
    max_out(B) B pairs (so witnesses <= |A|·max_out(B), symmetrically
    <= |B|·max_in(A)), and distinct result pairs additionally fit the
    endpoint grid V(A.s)·V(B.t).  The witness bound lands in
    ``max_join`` — it sizes the pre-dedup expansion buffer
    (``QueryCaps.join_cap``), where the uniform estimate's
    under-sizing on skewed fanout is exactly what used to ladder the
    caps (ROADMAP's C4 case).  Endpoint profiles propagate: sources of
    A∘B are sources of A, targets are targets of B, fanouts compose
    multiplicatively."""
    a, b = el.pairs, er.pairs
    if a <= 0 or b <= 0:
        return PlanEstimate(None, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    v = float(max(1, n_vertices))
    dd, ds = min(el.d_dst, v), min(er.d_src, v)  # unknown (inf) -> |V|
    witnesses = min(a * b, a * er.max_out, b * el.max_in)
    upper = min(witnesses, min(el.d_src, v) * min(er.d_dst, v))
    out = min(max(1.0, a * b / max(1.0, dd, ds)), max(1.0, upper))
    return PlanEstimate(
        None, out, 0.0, 0.0, max_join=max(1.0, witnesses),
        d_src=min(el.d_src, out), d_dst=min(er.d_dst, out),
        max_out=el.max_out * er.max_out, max_in=el.max_in * er.max_in)


def _leaf_est(seq: tuple, stats: IndexStats, table=None) -> PlanEstimate:
    """Profile of one indexed segment: exact cardinalities, and exact
    endpoint statistics when the view carries the pair columns."""
    cls = float(stats.seq_classes(seq))
    p = float(stats.seq_pairs(seq))
    ns = _ns(table, "lookup", cls)
    ep = stats.seq_endpoints(seq)
    if ep is None:
        return PlanEstimate(cls, p, cls, 0.0, 0.0, cost_ns=ns)
    return PlanEstimate(cls, p, cls, 0.0, 0.0,
                        d_src=float(ep.d_src), d_dst=float(ep.d_dst),
                        max_out=float(ep.max_out), max_in=float(ep.max_in),
                        cost_ns=ns)


def _conj_endpoints(el: PlanEstimate, er: PlanEstimate, pairs: float):
    """Endpoint profile of an intersection — a subset of both sides."""
    return dict(d_src=min(el.d_src, er.d_src, pairs),
                d_dst=min(el.d_dst, er.d_dst, pairs),
                max_out=min(el.max_out, er.max_out),
                max_in=min(el.max_in, er.max_in))


def _est(node, stats: IndexStats, table=None) -> PlanEstimate:
    kind = node[0]
    if kind == "lookup":
        segs = node[1]
        cur = _leaf_est(tuple(segs[0]), stats, table)
        if len(segs) == 1:
            return cur
        # multi-segment chain: every segment materializes, then folds
        # left-to-right through expansion joins (the walker's semantics)
        cost, maxp, maxj = cur.pairs, cur.pairs, 0.0
        ns = cur.cost_ns + _ns(table, "materialize", cur.pairs)
        for seg in segs[1:]:
            nxt = _leaf_est(tuple(seg), stats, table)
            out = join_est(cur, nxt, stats.n_vertices)
            cost += nxt.pairs + out.pairs
            ns += (nxt.cost_ns + _ns(table, "materialize", nxt.pairs)
                   + _ns(table, "join", out.pairs))
            maxp = max(maxp, nxt.pairs, out.pairs)
            maxj = max(maxj, out.max_join)  # pre-dedup witness bound
            cur = out
        return PlanEstimate(None, cur.pairs, cost, maxp, maxj,
                            d_src=cur.d_src, d_dst=cur.d_dst,
                            max_out=cur.max_out, max_in=cur.max_in,
                            cost_ns=ns)
    if kind == "identity":
        v = float(stats.n_vertices)
        return PlanEstimate(None, v, v, v, 0.0,
                            d_src=v, d_dst=v, max_out=1.0, max_in=1.0,
                            cost_ns=_ns(table, "identity", v))
    if kind == "conj_id":
        e = _est(node[1], stats, table)
        if e.classes is not None:
            inner = node[1]
            if inner[0] == "lookup" and len(inner[1]) == 1:
                pairs = float(stats.seq_cyclic_pairs(tuple(inner[1][0])))
            else:
                pairs = min(e.pairs, float(stats.n_vertices))
            return PlanEstimate(e.classes, pairs, e.cost + e.classes,
                                e.max_pairs, e.max_join,
                                d_src=pairs, d_dst=pairs,
                                max_out=1.0, max_in=1.0,
                                cost_ns=e.cost_ns
                                + _ns(table, "conjoin", e.classes))
        pairs = min(e.pairs, float(stats.n_vertices))
        return PlanEstimate(None, pairs, e.cost + e.pairs,
                            max(e.max_pairs, e.pairs), e.max_join,
                            d_src=pairs, d_dst=pairs,
                            max_out=1.0, max_in=1.0,
                            cost_ns=e.cost_ns
                            + _ns(table, "conjoin", e.pairs))
    if kind == "conj":
        el = _est(node[1], stats, table)
        er = _est(node[2], stats, table)
        maxj = max(el.max_join, er.max_join)
        if el.classes is not None and er.classes is not None:
            # Prop. 4.1: class-id intersection; |result ∩| pairs is
            # bounded by either side's total (a sound upper bound)
            cls = min(el.classes, er.classes)
            pairs = min(el.pairs, er.pairs)
            return PlanEstimate(cls, pairs,
                                el.cost + er.cost + cls,
                                max(el.max_pairs, er.max_pairs), maxj,
                                **_conj_endpoints(el, er, pairs),
                                cost_ns=el.cost_ns + er.cost_ns
                                + _ns(table, "conjoin",
                                      el.classes + er.classes))
        lp, rp = el.pairs, er.pairs  # both sides materialize
        pairs = min(lp, rp)
        return PlanEstimate(None, pairs,
                            el.cost + er.cost + lp + rp,
                            max(el.max_pairs, er.max_pairs, lp, rp), maxj,
                            **_conj_endpoints(el, er, pairs),
                            cost_ns=el.cost_ns + er.cost_ns
                            + _ns(table, "materialize", lp)
                            + _ns(table, "materialize", rp)
                            + _ns(table, "conjoin", lp + rp))
    if kind == "join":
        el = _est(node[1], stats, table)
        er = _est(node[2], stats, table)
        lp, rp = el.pairs, er.pairs
        out = join_est(el, er, stats.n_vertices)
        return PlanEstimate(None, out.pairs,
                            el.cost + er.cost + lp + rp + out.pairs,
                            max(el.max_pairs, er.max_pairs, lp, rp,
                                out.pairs),
                            max(el.max_join, er.max_join, out.max_join),
                            d_src=out.d_src, d_dst=out.d_dst,
                            max_out=out.max_out, max_in=out.max_in,
                            cost_ns=el.cost_ns + er.cost_ns
                            + _ns(table, "materialize", lp)
                            + _ns(table, "materialize", rp)
                            + _ns(table, "join", out.pairs))
    raise ValueError(kind)


def estimate_plan(plan, stats: IndexStats, cost_table=None) -> PlanEstimate:
    """Estimate a whole plan *including* the final materialization (a
    class-space result is expanded to pairs at the very end — the
    epilogue of the plan walker).  With a ``cost_table`` the profile also
    carries ``cost_ns`` — the same row estimates priced through the
    table's fitted per-operator stage constants."""
    e = _est(plan, stats, cost_table)
    if e.classes is None:
        return e
    return PlanEstimate(e.classes, e.pairs, e.cost + e.pairs,
                        max(e.max_pairs, e.pairs), e.max_join,
                        d_src=e.d_src, d_dst=e.d_dst,
                        max_out=e.max_out, max_in=e.max_in,
                        cost_ns=e.cost_ns
                        + _ns(cost_table, "materialize", e.pairs))


# ---------------------------------------------------------------------- #
# plan enumeration
# ---------------------------------------------------------------------- #


def enumerate_splits(seq: tuple, k: int, available,
                     limit: int = MAX_SPLIT_ENUM) -> list | None:
    """All segmentations of ``seq`` into contiguous parts of length <= k,
    each part present in ``available`` (length-1 parts are always legal:
    L_q ⊇ L).  Returns None when the count would exceed ``limit`` (the
    caller falls back to the greedy split)."""
    out: list = []

    def rec(i: int, acc: list) -> bool:
        if i == len(seq):
            out.append(list(acc))
            return len(out) <= limit
        for step in range(1, min(k, len(seq) - i) + 1):
            part = tuple(seq[i: i + step])
            if step > 1 and available is not None and part not in available:
                continue
            acc.append(part)
            ok = rec(i + step, acc)
            acc.pop()
            if not ok:
                return False
        return True

    return out if rec(0, []) else None


def _best_split(labels: tuple, k: int, stats: IndexStats, available,
                table=None) -> list:
    """Cheapest valid segmentation of one label run.

    A run that fits one indexed segment is provably optimal — its
    materialization is exactly the answer, and every split must
    materialize that same answer *plus* its own leaves — so it
    short-circuits (this is also the paper's Sec. VI-D observation that
    a diameter-k chain on a k-index is a single lookup).

    With a cost table the objective is ``cost_ns`` — whose per-stage
    fixed dispatch constants penalize extra segments, so a split that
    wins on rows but loses on launch overhead (ROADMAP's C4 case at CI
    scale) is no longer chosen.  The tie-break (fewer segments, then
    lexicographic) is identical either way."""
    labels = tuple(labels)
    if len(labels) <= k and (available is None or labels in available
                             or len(labels) == 1):
        return [labels]
    cands = enumerate_splits(labels, k, available)
    if not cands:
        return _split_seq(labels, k, available)
    best, best_key = None, None
    for segs in cands:
        items = [("lookup", [s]) for s in segs]
        _, cost = _chain_dp(items, stats, table)
        key = (cost, len(segs), tuple(segs))
        if best_key is None or key < best_key:
            best, best_key = segs, key
    return best


def _chain_dp(items: list, stats: IndexStats, table=None):
    """Re-associate a join chain (order fixed, grouping free) by interval
    DP over estimated intermediate cardinalities.  Interval cardinality
    is computed once per interval (left-extension), so every grouping of
    the same interval shares one estimate and the DP is consistent.
    Returns (plan tree, estimated cost) — cost in the table's ``cost_ns``
    nanoseconds when one is present (each join step then pays its fitted
    fixed stage constants, not just its rows), in rows otherwise."""
    n = len(items)
    ests = [estimate_plan(it, stats, table) for it in items]
    if table is None:
        base = [e.cost for e in ests]

        def step(left, right, out):
            return left.pairs + right.pairs + out.pairs
    else:
        base = [e.cost_ns for e in ests]

        def step(left, right, out):
            return (table.stage_ns("materialize", left.pairs)
                    + table.stage_ns("materialize", right.pairs)
                    + table.stage_ns("join", out.pairs))

    if n == 1:
        return items[0], base[0]
    prof = [[None] * n for _ in range(n)]  # interval cardinality profile
    cost = [[0.0] * n for _ in range(n)]
    cut = [[0] * n for _ in range(n)]
    for i in range(n):
        prof[i][i] = ests[i]
        cost[i][i] = base[i]
    for span in range(2, n + 1):
        for i in range(0, n - span + 1):
            j = i + span - 1
            prof[i][j] = join_est(prof[i][j - 1], prof[j][j],
                                  stats.n_vertices)
            best, best_m = None, i
            for m in range(i, j):
                c = (cost[i][m] + cost[m + 1][j]
                     + step(prof[i][m], prof[m + 1][j], prof[i][j]))
                if best is None or c < best:
                    best, best_m = c, m
            cost[i][j], cut[i][j] = best, best_m

    def build(i: int, j: int):
        if i == j:
            return items[i]
        m = cut[i][j]
        return ("join", build(i, m), build(m + 1, j))

    return build(0, n - 1), cost[0][n - 1]


def _fuse_lookups(node):
    """Fold ``join(lookup[segs...], lookup[single])`` into one multi-
    segment LOOKUP node — the walker evaluates a LOOKUP's segments as
    exactly that left-deep join chain, so the fusion never changes the
    association the DP chose; it only shares the jit shape with the
    syntactic planner's output."""
    kind = node[0]
    if kind == "join":
        l = _fuse_lookups(node[1])
        r = _fuse_lookups(node[2])
        if l[0] == "lookup" and r[0] == "lookup" and len(r[1]) == 1:
            return ("lookup", list(l[1]) + list(r[1]))
        return ("join", l, r)
    if kind == "conj":
        return ("conj", _fuse_lookups(node[1]), _fuse_lookups(node[2]))
    if kind == "conj_id":
        return ("conj_id", _fuse_lookups(node[1]))
    return node


def _flatten_conj(q: CPQ) -> list:
    if isinstance(q, Conj):
        return _flatten_conj(q.lhs) + _flatten_conj(q.rhs)
    return [q]


def _opt(q: CPQ, k: int, stats: IndexStats, available, table=None):
    if isinstance(q, Edge):
        return ("lookup", [(q.label,)])
    if isinstance(q, Identity):
        return ("identity",)
    if isinstance(q, Conj):
        ops = _flatten_conj(q)
        rest = [o for o in ops if not isinstance(o, Identity)]
        if not rest:
            return ("identity",)  # id ∩ id ∩ ... == id
        plans = [_opt(o, k, stats, available, table) for o in rest]
        # ∩ is idempotent: identical operands (e.g. the shared edge of
        # the TT template) evaluate once
        deduped = {freeze_plan(p): p for p in plans}
        # commutative: smallest estimated operand first, so the running
        # intersection (the probed side) stays as small as possible
        # (row-based on purpose: the smallest-first rule is about probe
        # sizes, which stage constants don't change)
        keyed = []
        for frozen, p in deduped.items():
            e = estimate_plan(p, stats)
            keyed.append(((e.pairs, e.classes is None, repr(frozen)), p))
        keyed.sort(key=lambda kp: kp[0])
        plans = [p for _, p in keyed]
        node = plans[0]
        for nxt in plans[1:]:
            node = ("conj", node, nxt)
        if len(rest) < len(ops):  # had an identity operand: q ∩ id
            node = ("conj_id", node)
        return node
    if isinstance(q, Join):
        leaves = _flatten_join(q)
        items: list = []
        run: list = []
        for leaf in leaves + [None]:  # None flushes the trailing run
            if isinstance(leaf, Edge):
                run.append(leaf.label)
                continue
            if run:
                items.extend(("lookup", [s]) for s in
                             _best_split(tuple(run), k, stats, available,
                                         table))
                run = []
            if leaf is not None:
                items.append(_opt(leaf, k, stats, available, table))
        if len(items) == 1:
            return items[0]
        tree, _ = _chain_dp(items, stats, table)
        return _fuse_lookups(tree)
    raise TypeError(q)


def optimize_query(q: CPQ, k: int, stats: IndexStats, available=None,
                   cost_table=None):
    """Compile an AST to a cost-optimized physical plan.

    Same contract as :func:`repro.core.query.plan_query` (the syntactic
    fallback), same plan language, same answers — only operator order,
    join association, and segment splits differ, chosen to minimize the
    cost model over ``stats``.  ``available`` restricts LOOKUP segments
    exactly as in the syntactic planner (iaCPQx query-time splitting).

    ``cost_table`` (a :class:`~repro.core.costmodel.DeviceCostTable`)
    switches the split/association objective from rows to calibrated
    device nanoseconds; None keeps the row objective bit-for-bit — a
    mispriced table can change capacities and plan choice but never
    answers (the overflow ladder's contract)."""
    q = _strip_identity_joins(q)
    if isinstance(q, Identity):
        return ("identity",)
    return _opt(q, k, stats, available, cost_table)
