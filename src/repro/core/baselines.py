"""Baselines: the state-of-the-art language-unaware path index [14]
(inverted index: label sequence -> s-t pairs) and index-free BFS.

The Path index shares the CPQx path enumeration — its payload is exactly
the (seq, v, u) incidence relation, CSR-organized by sequence.  Its
evaluator executes the *same* physical plans as CPQx but has no class
space: every operator works on materialized pair sets.  That contrast is
the paper's headline measurement (Fig. 6 / Table III): conjunctions cost
|pairs| here vs |classes| with CPQx.

``iaPath`` (interest-filtered variant) is the same structure built over
the L_q-filtered rows.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .capacity import BuildCaps, estimate_build_caps
from .engine import QueryCaps, _join_pairs
from .graph import LabeledGraph
from .interest import normalize_interests
from .paths import DeviceGraph, _recap, device_graph, enumerate_path_levels, seq_rows_of_levels
from .query import CPQ, plan_query, plan_lookup_seqs


class PathArrays(NamedTuple):
    seq_table: jax.Array  # (n_seq_cap, k) padded -1, sorted
    seq_count: jax.Array
    seq_starts: jax.Array
    seq_ends: jax.Array
    l2p_v: jax.Array  # rows sorted by (seq, v, u)
    l2p_u: jax.Array
    l2p_count: jax.Array
    overflow: jax.Array


@functools.partial(jax.jit, static_argnames=("k", "caps_key", "interest_key"))
def build_path_arrays(dg: DeviceGraph, k: int, caps_key: tuple,
                      interest_key: tuple | None = None) -> PathArrays:
    caps = BuildCaps(*caps_key)
    levels = enumerate_path_levels(dg, k, caps.level_rows)
    rows = seq_rows_of_levels(levels, k, caps.seq_rows)  # (s1..sk, v, u) sorted
    overflow = rows.overflow
    for lvl in levels:
        overflow = overflow | lvl.overflow
    if interest_key is not None:
        itable = jnp.asarray(np.array(interest_key, np.int32))
        icols = tuple(itable[:, j] for j in range(k))
        cnt = R.lex_count_matches(icols, rows.cols[:k],
                                  jnp.asarray(itable.shape[0], R.I32))
        rows = R.rel_compact(rows, cnt > 0)

    seqs = R.rel_unique(rows, num_keys=k)
    seqs = _recap(R.Relation(seqs.cols[:k], seqs.count, seqs.overflow),
                  caps.n_seqs)
    starts = R.lex_searchsorted(rows.cols[:k], seqs.cols, "left").astype(R.I32)
    ends = R.lex_searchsorted(rows.cols[:k], seqs.cols, "right").astype(R.I32)
    validm = R.valid_mask(seqs)
    starts = jnp.where(validm, starts, 0)
    ends = jnp.where(validm, ends, 0)
    return PathArrays(
        seq_table=jnp.stack(seqs.cols, axis=1), seq_count=seqs.count,
        seq_starts=starts, seq_ends=ends,
        l2p_v=rows.cols[k], l2p_u=rows.cols[k + 1], l2p_count=rows.count,
        overflow=overflow | seqs.overflow,
    )


@dataclasses.dataclass
class PathIndex:
    k: int
    n_vertices: int
    arrays: PathArrays
    seq_ranges: dict
    interests: frozenset | None = None

    def size_entries(self) -> int:
        return int(self.arrays.l2p_count)

    def lookup_range(self, seq: tuple) -> tuple[int, int]:
        return self.seq_ranges.get(tuple(seq), (0, 0))


def build_path(g: LabeledGraph, k: int,
               interests: Iterable[tuple] | None = None,
               caps: BuildCaps | None = None) -> PathIndex:
    if caps is None:
        caps = estimate_build_caps(g, k)
    ikey = normalize_interests(g, k, interests) if interests is not None else None
    dg = device_graph(g)
    arrays = build_path_arrays(dg, k, caps.key(), ikey)
    if bool(arrays.overflow):
        raise RuntimeError("path index build overflow")
    n = int(arrays.seq_count)
    table = np.asarray(arrays.seq_table)[:n]
    st = np.asarray(arrays.seq_starts)[:n]
    en = np.asarray(arrays.seq_ends)[:n]
    ranges = {
        tuple(int(x) for x in row if x >= 0): (int(s), int(e))
        for row, s, e in zip(table, st, en)
    }
    return PathIndex(
        k=k, n_vertices=g.n_vertices, arrays=arrays, seq_ranges=ranges,
        interests=(frozenset(tuple(x for x in s if x >= 0) for s in ikey)
                   if ikey is not None else None),
    )


# ---------------------------------------------------------------------- #
# evaluator — same plans, pair space only
# ---------------------------------------------------------------------- #


def _lookup_pairs(a: PathArrays, start, length, cap: int) -> R.Relation:
    idx = jnp.arange(cap, dtype=R.I32)
    valid = idx < length
    src = jnp.clip(start + idx, 0, a.l2p_v.shape[0] - 1)
    v = jnp.where(valid, a.l2p_v[src], R.SENTINEL)
    u = jnp.where(valid, a.l2p_u[src], R.SENTINEL)
    # rows within a seq block are sorted by (v, u) and distinct
    return R.Relation((v, u), jnp.minimum(length, cap).astype(R.I32),
                      length > cap)


@functools.partial(jax.jit, static_argnames=("plan", "caps", "n_vertices"))
def run_plan_path(a: PathArrays, plan, caps: QueryCaps, n_vertices: int,
                  lookup_ranges: jax.Array):
    counter = [0]

    def next_range():
        i = counter[0]
        counter[0] += 1
        return lookup_ranges[i, 0], lookup_ranges[i, 1]

    def ev(node):
        kind = node[0]
        if kind == "lookup":
            nseg = node[1] if isinstance(node[1], int) else len(node[1])
            start, length = next_range()
            cur = _lookup_pairs(a, start, length, caps.pair_cap)
            for _ in range(nseg - 1):
                start, length = next_range()
                nxt = _lookup_pairs(a, start, length, caps.pair_cap)
                cur = _join_pairs(cur, nxt, caps.join_cap, caps.pair_cap)
            return cur
        if kind == "identity":
            v = jnp.arange(caps.pair_cap, dtype=R.I32)
            m = v < n_vertices
            col = jnp.where(m, v, R.SENTINEL)
            return R.Relation((col, col),
                              jnp.asarray(min(n_vertices, caps.pair_cap), R.I32),
                              jnp.asarray(n_vertices > caps.pair_cap))
        if kind == "conj_id":
            rel = ev(node[1])
            return R.rel_compact(rel, rel.cols[0] == rel.cols[1])
        left = ev(node[1])
        right = ev(node[2])
        if kind == "conj":
            return R.rel_intersect(left, right, 2)
        if kind == "join":
            return _join_pairs(left, right, caps.join_cap, caps.pair_cap)
        raise ValueError(kind)

    pairs = ev(plan)
    return pairs, pairs.overflow


class PathEngine:
    def __init__(self, index: PathIndex):
        self.index = index
        self._available = (set(index.seq_ranges)
                           if index.interests is not None else None)

    def execute(self, q: CPQ, caps: QueryCaps | None = None,
                max_retries: int = 8) -> np.ndarray:
        from .query import plan_shape

        plan = plan_query(q, self.index.k, available=self._available)
        seqs = plan_lookup_seqs(plan)
        ranges = np.array([self.index.lookup_range(s) for s in seqs],
                          np.int32).reshape(-1, 2)
        ranges[:, 1] = ranges[:, 1] - ranges[:, 0]
        if caps is None:
            n = max(16, int(self.index.arrays.l2p_count))
            p2 = 1 << (n - 1).bit_length()
            caps = QueryCaps(class_cap=16, pair_cap=p2, join_cap=2 * p2)
        for _ in range(max_retries):
            pairs, overflow = run_plan_path(
                self.index.arrays, plan_shape(plan), caps, self.index.n_vertices,
                jnp.asarray(ranges),
            )
            if not bool(overflow):
                return R.to_numpy(pairs)
            caps = caps.doubled()
        raise RuntimeError("query overflow not resolved after retries")
