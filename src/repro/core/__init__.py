"""The paper's contribution: CPQ-aware path indexing (CPQx / iaCPQx),
the capacity-padded relational substrate, the backend-agnostic query
engine (``backend`` — local; ``distributed`` — whole plans inside
shard_map over a ``sharded_index`` layout), the cost-based optimizer
(``optimizer`` over the ``stats`` view), lazy maintenance, the
workload-adaptive interest miner (``workload`` — sketch, benefit model
and adaptation controller closing the serving loop back to the iaCPQx
interest set), baselines, and the semantics oracle.
``docs/ARCHITECTURE.md`` maps how the modules fit together."""
