"""Sharded CPQx index layout — first-class distribution of the index
arrays over one mesh axis.

The layout follows the paper's size asymmetry (Sec. VI: the class space
stays tiny even when the pair space grows with the graph):

* **I_c2p sharded by class hash** — the c2p pair columns are partitioned
  so every equivalence class lives whole on exactly one shard, with a
  *per-shard* CSR (``class_starts[s, c]``) over global class ids.  A
  shard materializes only its own classes; classes are disjoint in pair
  space, so sharded materialization never produces cross-shard
  duplicates.
* **pair table sharded by (v, u)** — the by-(v,u)-sorted pair table is
  hash-partitioned on both endpoints (the canonical pair-space
  distribution).
* **seq / l2c / cycle metadata replicated** — I_l2c class lists and the
  per-class cycle flags are small (the paper's central observation), so
  every shard carries a full copy and class-space query work needs no
  communication at all.

``shard_index`` / ``gather_index`` convert between this layout and the
single-device :class:`~repro.core.index.DeviceIndexArrays`; the shard
capacities derive from the device capacities (stable across maintenance
flushes, so ``Engine.rebind`` after a flush reshards into arrays of the
same shape and keeps the jit cache warm) and grow-and-retry on skew
(the host twin of the device overflow ladder specified in the
``core.backend`` module docstring).

Because the planning metadata is replicated, the cost-based optimizer's
statistics are too: :func:`replicated_stats` rebuilds the exact
:class:`~repro.core.stats.IndexStats` of the pre-shard index from a
sharded layout alone, so a planner next to any shard reorders plans
identically to a local engine — sharded planning can never drift from
local planning.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import relational as R
from .index import CPQxIndex, DeviceIndexArrays

from .relational import _MIX_A, _MIX_B, SHARD_SALT  # single hash source


class ShardedIndexArrays(NamedTuple):
    """A built index distributed over ``n_shards`` (a pytree).

    Sharded leaves carry a leading ``(n_shards, ...)`` axis (placed on
    the mesh axis by ``shard_map`` with spec ``P(axis)``); replicated
    leaves keep the single-device shape (spec ``P()``)."""

    # pair table sorted by (v, u), hash-partitioned on (v, u)
    pair_v: jax.Array  # (n_shards, pair_shard_cap)
    pair_u: jax.Array
    pair_cls: jax.Array
    pair_counts: jax.Array  # (n_shards,)
    # I_c2p sorted by (class, v, u), hash-partitioned on class
    c2p_cls: jax.Array  # (n_shards, c2p_shard_cap)
    c2p_v: jax.Array
    c2p_u: jax.Array
    c2p_counts: jax.Array  # (n_shards,)
    class_starts: jax.Array  # (n_shards, class_cap + 1) per-shard CSR
    # replicated: class-space + lookup metadata (small by Sec. VI)
    class_cyclic: jax.Array
    n_classes: jax.Array
    seq_table: jax.Array
    seq_count: jax.Array
    seq_starts: jax.Array
    seq_ends: jax.Array
    l2c_cls: jax.Array
    l2c_count: jax.Array

    @property
    def n_shards(self) -> int:
        return self.c2p_v.shape[0]


_SHARDED_FIELDS = frozenset({
    "pair_v", "pair_u", "pair_cls", "pair_counts",
    "c2p_cls", "c2p_v", "c2p_u", "c2p_counts", "class_starts",
})


def index_specs(axis: str) -> ShardedIndexArrays:
    """The ``shard_map`` in_specs pytree for :class:`ShardedIndexArrays`."""
    return ShardedIndexArrays(**{
        f: (P(axis) if f in _SHARDED_FIELDS else P())
        for f in ShardedIndexArrays._fields
    })


# ---------------------------------------------------------------------- #
# host-side hash partitioning (vectorized; must agree with the device)
# ---------------------------------------------------------------------- #


def _mix32_np(x: np.ndarray, salt: int) -> np.ndarray:
    """Numpy twin of ``relational.mix32`` (wrapping uint32 avalanche)."""
    h = x.astype(np.uint32) ^ np.uint32(salt)
    h = (h ^ (h >> np.uint32(16))) * _MIX_A
    h = (h ^ (h >> np.uint32(15))) * _MIX_B
    return h ^ (h >> np.uint32(16))


def hash_buckets(rows: np.ndarray, key_cols: Sequence[int],
                 n_shards: int) -> np.ndarray:
    """Shard owning each row: single-column keys reproduce the device's
    ``_bucket_of`` exactly (so host placement == device repartitioning);
    multi-column keys fold left with the same mix."""
    h = _mix32_np(rows[:, key_cols[0]], SHARD_SALT)
    for j in key_cols[1:]:
        h = _mix32_np(rows[:, j].astype(np.uint32) ^ h, SHARD_SALT)
    return (h % np.uint32(n_shards)).astype(np.int64)


def partition_rows(rows: np.ndarray, n_shards: int, cap: int,
                   key_cols: Sequence[int] = (0,), grow: bool = True):
    """Hash-partition host rows into ``(n_shards, cap, arity)`` blocks,
    each shard's rows sorted lexicographically and SENTINEL-padded.

    Fully vectorized (one lexsort + searchsorted bucket boundaries + one
    flat scatter — no per-shard Python loop).  A shard overflowing ``cap``
    doubles the capacity and retries (the host twin of the device's
    flagged grow-and-retry) unless ``grow=False``, which raises instead.

    Returns ``(blocks, counts, cap)`` — ``cap`` is the possibly-grown
    per-shard capacity."""
    rows = np.asarray(rows, np.int32).reshape(-1, rows.shape[-1])
    n, arity = rows.shape
    bucket = hash_buckets(rows, tuple(key_cols), n_shards)
    # one lexsort: primary key bucket, then the row columns in order
    order = np.lexsort(
        tuple(rows[:, j] for j in range(arity - 1, -1, -1)) + (bucket,))
    srows, sb = rows[order], bucket[order]
    offs = np.searchsorted(sb, np.arange(n_shards), side="left")
    ends = np.searchsorted(sb, np.arange(n_shards), side="right")
    counts = (ends - offs).astype(np.int32)
    biggest = int(counts.max()) if n_shards else 0
    if biggest > cap:
        if not grow:
            raise ValueError(
                f"shard overflow: {biggest} rows > capacity {cap}")
        while biggest > cap:
            cap *= 2
    out = np.full((n_shards, cap, arity), R.SENTINEL, np.int32)
    slot = np.arange(n) - offs[sb]  # position within the shard block
    out.reshape(-1, arity)[sb * cap + slot] = srows
    return out, counts, cap


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


# ---------------------------------------------------------------------- #
# shard / gather
# ---------------------------------------------------------------------- #


def shard_index(index: CPQxIndex, n_shards: int,
                min_cap: int = 64) -> ShardedIndexArrays:
    """Distribute a built index into :class:`ShardedIndexArrays`.

    Per-shard capacities start at ``2/n_shards`` of the device capacity
    (power-of-two, so a balanced hash fits with 2x headroom) and grow on
    skew.  Deriving from the *capacity* rather than the live count keeps
    shard shapes — and the compiled sharded executables keyed on them —
    stable across maintenance flushes."""
    a = index.arrays
    base = int(a.c2p_v.shape[0])
    cap0 = _pow2(max(min_cap, min(base, -(-2 * base // max(1, n_shards)))))

    n_pairs = int(a.pair_count)
    pair_rows = np.stack([
        np.asarray(a.pair_v)[:n_pairs], np.asarray(a.pair_u)[:n_pairs],
        np.asarray(a.pair_cls)[:n_pairs]], axis=1)
    pair_blocks, pair_counts, _ = partition_rows(
        pair_rows.reshape(-1, 3), n_shards, cap0, key_cols=(0, 1))

    c2p_rows = np.stack([
        np.asarray(a.c2p_cls)[:n_pairs], np.asarray(a.c2p_v)[:n_pairs],
        np.asarray(a.c2p_u)[:n_pairs]], axis=1)
    c2p_blocks, c2p_counts, _ = partition_rows(
        c2p_rows.reshape(-1, 3), n_shards, cap0, key_cols=(0,))

    # per-shard CSR over global class ids: the padded class column is
    # ascending (SENTINEL pads sort last), so searchsorted per shard
    n_starts = int(a.class_starts.shape[0])
    ids = np.arange(n_starts, dtype=np.int64)
    class_starts = np.stack([
        np.searchsorted(c2p_blocks[s, :, 0].astype(np.int64), ids, side="left")
        for s in range(n_shards)]).astype(np.int32)

    return ShardedIndexArrays(
        pair_v=jnp.asarray(pair_blocks[:, :, 0]),
        pair_u=jnp.asarray(pair_blocks[:, :, 1]),
        pair_cls=jnp.asarray(pair_blocks[:, :, 2]),
        pair_counts=jnp.asarray(pair_counts),
        c2p_cls=jnp.asarray(c2p_blocks[:, :, 0]),
        c2p_v=jnp.asarray(c2p_blocks[:, :, 1]),
        c2p_u=jnp.asarray(c2p_blocks[:, :, 2]),
        c2p_counts=jnp.asarray(c2p_counts),
        class_starts=jnp.asarray(class_starts),
        class_cyclic=a.class_cyclic, n_classes=a.n_classes,
        seq_table=a.seq_table, seq_count=a.seq_count,
        seq_starts=a.seq_starts, seq_ends=a.seq_ends,
        l2c_cls=a.l2c_cls, l2c_count=a.l2c_count,
    )


def replicated_stats(sharded: ShardedIndexArrays, n_vertices: int,
                     k: int) -> "IndexStats":
    """The optimizer's :class:`~repro.core.stats.IndexStats`, derived
    entirely from a sharded layout: the seq/l2c/cyclic metadata is
    replicated, and per-class pair counts fall out of the per-shard CSRs
    — every class lives whole on exactly one shard, so summing the
    per-shard extents over the shard axis reconstructs the global class
    sizes exactly.  Bit-identical to ``IndexStats.from_index`` on the
    index that was sharded (tests pin this) — so a planner holding only
    the sharded layout (a migration target, a remote planner) reorders
    plans exactly as a local engine would."""
    from .index import _pull_seq_ranges  # sharded tuple has the seq fields
    from .stats import IndexStats

    starts = np.asarray(sharded.class_starts, np.int64)
    sizes = (starts[:, 1:] - starts[:, :-1]).sum(axis=0)
    # endpoint statistics need the actual pairs: every class lives whole
    # on one shard, so concatenating the valid per-shard prefixes and
    # re-sorting by class rebuilds the global (class, v, u) columns — the
    # distinct-endpoint/fanout numbers are order-insensitive within a
    # class, so this view is statistic-identical to the pre-shard one.
    # Deferred to the first seq_endpoints() call: the reassembly is
    # O(total pairs), far beyond the replicated few-KB metadata.
    def fetch():
        cc = np.asarray(sharded.c2p_counts)
        ccls, cv, cu = (np.asarray(x) for x in
                        (sharded.c2p_cls, sharded.c2p_v, sharded.c2p_u))
        rows = [np.stack([ccls[s, :cc[s]], cv[s, :cc[s]], cu[s, :cc[s]]], 1)
                for s in range(sharded.n_shards)]
        flat = (np.concatenate(rows) if rows
                else np.zeros((0, 3), np.int64))
        flat = flat[np.argsort(flat[:, 0].astype(np.int64), kind="stable")]
        return flat[:, 1], flat[:, 2]

    return IndexStats.from_host_arrays(
        n_vertices=n_vertices,
        n_classes=int(sharded.n_classes),
        total_pairs=int(np.asarray(sharded.c2p_counts).sum()),
        seq_ranges=_pull_seq_ranges(sharded, k),
        class_starts=np.concatenate([np.zeros(1, np.int64),
                                     np.cumsum(sizes)]),
        l2c_cls=np.asarray(sharded.l2c_cls),
        l2c_count=int(sharded.l2c_count),
        class_cyclic=np.asarray(sharded.class_cyclic),
        c2p_fetch=fetch,
    )


def gather_index(sharded: ShardedIndexArrays,
                 pair_cap: int | None = None) -> DeviceIndexArrays:
    """Collapse a sharded index back to single-device arrays (migration
    off a mesh, or the round-trip check in tests).  ``pair_cap`` pins the
    rebuilt pair/c2p capacity — pass the original device capacity to get
    arrays bit-identical to the pre-shard index."""
    pc = np.asarray(sharded.pair_counts)
    cc = np.asarray(sharded.c2p_counts)
    pv, pu, pcls = (np.asarray(x) for x in
                    (sharded.pair_v, sharded.pair_u, sharded.pair_cls))
    cv, cu, ccls = (np.asarray(x) for x in
                    (sharded.c2p_v, sharded.c2p_u, sharded.c2p_cls))
    n_shards = sharded.n_shards
    pair_rows = np.concatenate([
        np.stack([pv[s, :pc[s]], pu[s, :pc[s]], pcls[s, :pc[s]]], axis=1)
        for s in range(n_shards)]) if n_shards else np.zeros((0, 3), np.int32)
    c2p_rows = np.concatenate([
        np.stack([ccls[s, :cc[s]], cv[s, :cc[s]], cu[s, :cc[s]]], axis=1)
        for s in range(n_shards)]) if n_shards else np.zeros((0, 3), np.int32)
    pair_rows = pair_rows[np.lexsort(
        (pair_rows[:, 2], pair_rows[:, 1], pair_rows[:, 0]))]
    c2p_rows = c2p_rows[np.lexsort(
        (c2p_rows[:, 2], c2p_rows[:, 1], c2p_rows[:, 0]))]
    n = pair_rows.shape[0]
    cap = pair_cap if pair_cap is not None else _pow2(max(64, n))

    def pad(col):
        buf = np.full(cap, R.SENTINEL, np.int32)
        buf[:n] = col
        return jnp.asarray(buf)

    class_starts = np.searchsorted(
        np.concatenate([c2p_rows[:, 0],
                        np.full(cap - n, np.int64(R.SENTINEL))]).astype(np.int64),
        np.arange(cap + 1), side="left").astype(np.int32)
    return DeviceIndexArrays(
        pair_v=pad(pair_rows[:, 0]), pair_u=pad(pair_rows[:, 1]),
        pair_cls=pad(pair_rows[:, 2]),
        pair_count=jnp.asarray(n, R.I32),
        c2p_cls=pad(c2p_rows[:, 0]), c2p_v=pad(c2p_rows[:, 1]),
        c2p_u=pad(c2p_rows[:, 2]),
        class_starts=jnp.asarray(class_starts),
        class_cyclic=sharded.class_cyclic, n_classes=sharded.n_classes,
        seq_table=sharded.seq_table, seq_count=sharded.seq_count,
        seq_starts=sharded.seq_starts, seq_ends=sharded.seq_ends,
        l2c_cls=sharded.l2c_cls, l2c_count=sharded.l2c_count,
        overflow=jnp.asarray(False),
    )
